//! Deterministic PRNG substrate (no external `rand` crate is vendored).
//!
//! `Pcg32` is the PCG-XSH-RR 64/32 generator: small state, excellent
//! statistical quality, and — critically for the experiment harness —
//! stable streams: every (seed, stream) pair is an independent sequence, so
//! each simulated device, dataset shard and codec gets its own reproducible
//! randomness regardless of scheduling order.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id (any values are valid).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let lo = m as u32;
            if lo >= bound {
                return (m >> 32) as u32;
            }
            // rejection zone: only loop when lo < bound and lo < threshold
            let threshold = bound.wrapping_neg() % bound;
            if lo >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box-Muller (uses two uniforms per pair).
    pub fn next_gaussian(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from a symmetric Dirichlet(alpha, k) via Gamma(alpha) draws
    /// (Marsaglia-Tsang; alpha < 1 handled with the boost trick).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            // pathological underflow: fall back to one-hot on a random class
            let hot = self.below(k as u32) as usize;
            return (0..k).map(|i| if i == hot { 1.0 } else { 0.0 }).collect();
        }
        for v in &mut g {
            *v /= sum;
        }
        g
    }

    /// Gamma(shape, 1) sampler (Marsaglia & Tsang 2000).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.next_f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = {
                let u1 = self.next_f64().max(1e-12);
                let u2 = self.next_f64();
                (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
            };
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Pcg32::new(7, 1);
        let mut b = Pcg32::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::seeded(11);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::seeded(9);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            let sum: f64 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_small_alpha_is_peaky() {
        let mut r = Pcg32::seeded(13);
        let p = r.dirichlet(0.1, 10);
        let max = p.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.3, "alpha=0.1 should concentrate, max={max}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }
}
