//! Minimal JSON substrate (no serde is vendored for this image).
//!
//! Supports the full JSON grammar minus exotic escapes; used to parse the
//! AOT `manifest.json` contract and to emit metric/result files consumed by
//! the bench harness and plotting. Numbers parse to f64 (the manifest only
//! carries integers small enough for exact f64 representation).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic dumps.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][...]` chain; panics with a readable path on miss.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for key in path {
            cur = cur.get(key).unwrap_or_else(|| {
                panic!("json: missing key '{key}' in path {path:?}")
            });
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- serialization --------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing --------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let val = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(val)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("invalid number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let s = &b[*pos..];
                let len = utf8_len(s[0]);
                let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                    .map_err(|_| "invalid utf8")?;
                out.push_str(chunk);
                *pos += len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {}
            _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected object key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos}", pos = *pos));
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(j.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"flag":false,"n":null,"nested":{"k":3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_ok()); // deliberately lenient: trailing comma
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn real_manifest_fragment() {
        let src = r#"{"artifacts":{"entropy":{"file":"entropy.hlo.txt",
            "inputs":[{"name":"acts","dims":[32,32,16,16],"dtype":"f32"}]}}}"#;
        let j = Json::parse(src).unwrap();
        let inp = &j.at(&["artifacts", "entropy", "inputs"]).as_arr().unwrap()[0];
        let dims: Vec<usize> = inp
            .at(&["dims"])
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![32, 32, 16, 16]);
    }

    #[test]
    fn dump_escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.dump(), r#""a\"b\\c\nd""#);
    }
}
