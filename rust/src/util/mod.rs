//! Foundation substrates: deterministic RNG, JSON, statistics, logging, and
//! a mini property-testing harness. Everything here is dependency-free —
//! only `xla` and `anyhow` are vendored on this image, so the usual crates
//! (rand/serde/log/proptest) are reimplemented at the scale this project
//! needs.

pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
