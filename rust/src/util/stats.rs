//! Statistics substrate: online moments, percentiles, and the timing
//! aggregation used by the criterion-free bench harness.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (n); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1); 0 for fewer than 2 samples.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Summary of a sample batch: mean/std/min/max/percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Summary {
            n: samples.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Linear-interpolation percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64).sqrt()
}

/// Mean of f32 data as f64.
pub fn mean_f32(xs: &[f32]) -> f64 {
    mean(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
}

/// Population std of f32 data as f64.
pub fn std_f32(xs: &[f32]) -> f64 {
    std(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
}

/// Simple exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 6.2f64).powi(2)).sum::<f64>() / 5.0;
        assert!((w.variance() - naive_var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
        assert_eq!(a.count(), 100);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 0.5) - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_sane() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.p50 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..64 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
