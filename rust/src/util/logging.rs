//! Tiny leveled logger (no `log`/`env_logger` runtime deps on the hot path).
//!
//! Level is process-global, set once by the CLI (`--log-level`) or the
//! `SLACC_LOG` environment variable — both routes parse through
//! [`level_from_str`]. Macros compile to a branch on a relaxed atomic load,
//! so disabled levels cost ~1ns.
//!
//! Every line is prefixed with a monotonic elapsed-time stamp (seconds
//! since the process epoch — the same epoch [`crate::obs::span`] stamps
//! trace events with, so logs and spans line up) and the emitting thread's
//! name, and is formatted into one buffer before a single locked
//! `write_all`, so concurrent device/server threads cannot interleave
//! partial lines.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Process epoch for log stamps and span timestamps: first use pins it, so
/// call [`init_from_env`] early for stamps that start near zero.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process epoch (monotonic).
pub fn elapsed_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Initialize from `SLACC_LOG` if set; call once at startup (also pins the
/// elapsed-time epoch).
pub fn init_from_env() {
    let _ = epoch();
    if let Ok(v) = std::env::var("SLACC_LOG") {
        if let Some(l) = level_from_str(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let t = elapsed_ns() as f64 / 1e9;
        let cur = std::thread::current();
        let thread = cur.name().unwrap_or("?");
        // one formatted buffer, one locked write: no interleaved lines
        let line = format!("[{t:9.3}s {tag} {thread}] {args}\n");
        use std::io::Write;
        let stderr = std::io::stderr();
        let mut handle = stderr.lock();
        let _ = handle.write_all(line.as_bytes());
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn parse_levels() {
        assert_eq!(level_from_str("DEBUG"), Some(Level::Debug));
        assert_eq!(level_from_str("nope"), None);
    }

    #[test]
    fn elapsed_is_monotonic() {
        let a = elapsed_ns();
        let b = elapsed_ns();
        assert!(b >= a);
    }
}
