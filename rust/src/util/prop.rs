//! Mini property-testing harness (proptest is not vendored on this image).
//!
//! `Prop` drives a closure over many PCG-seeded cases and, on failure,
//! re-runs a deterministic shrink loop over the failing seed's "size" knob.
//! It is intentionally small: generators are free functions over `Pcg32`
//! plus a `size` hint, which is all the coordinator invariants need
//! (routing/batching/codec round-trips over random tensors).
//!
//! ```ignore
//! Prop::new("quant roundtrip").cases(200).run(|rng, size| {
//!     let n = 1 + rng.below(size as u32) as usize;
//!     ...check invariant, return Err(msg) to fail...
//! });
//! ```

use super::rng::Pcg32;

pub struct Prop {
    name: &'static str,
    cases: usize,
    seed: u64,
    max_size: usize,
}

impl Prop {
    pub fn new(name: &'static str) -> Self {
        Prop { name, cases: 100, seed: 0x5eed, max_size: 64 }
    }

    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn max_size(mut self, s: usize) -> Self {
        self.max_size = s;
        self
    }

    /// Run the property. The closure gets a fresh deterministic RNG per case
    /// and a size hint that ramps up 1..=max_size over the run.
    pub fn run<F>(self, mut f: F)
    where
        F: FnMut(&mut Pcg32, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let size = 1 + (case * self.max_size) / self.cases.max(1);
            let mut rng = Pcg32::new(self.seed, case as u64);
            if let Err(msg) = f(&mut rng, size) {
                // shrink: retry the same case stream with smaller sizes
                let mut min_fail = (size, msg.clone());
                let mut s = size / 2;
                while s >= 1 {
                    let mut rng2 = Pcg32::new(self.seed, case as u64);
                    match f(&mut rng2, s) {
                        Err(m) => {
                            min_fail = (s, m);
                            if s == 1 {
                                break;
                            }
                            s /= 2;
                        }
                        Ok(()) => break,
                    }
                }
                panic!(
                    "property '{}' failed (case {case}, seed {:#x}, size {}): {}",
                    self.name, self.seed, min_fail.0, min_fail.1
                );
            }
        }
    }
}

/// Generate a random f32 vector with mixed magnitudes (exercises both
/// subnormal-ish and large values).
pub fn vec_f32(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    let scale = 10f32.powi(rng.below(7) as i32 - 3);
    (0..len).map(|_| rng.next_gaussian() * scale).collect()
}

/// Random vector guaranteed to contain at least two distinct values.
pub fn vec_f32_nonflat(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    let mut v = vec_f32(rng, len.max(2));
    if v.iter().all(|&x| x == v[0]) {
        v[0] += 1.0;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("reverse twice").cases(50).run(|rng, size| {
            let v: Vec<u32> = (0..size).map(|_| rng.next_u32()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w { Ok(()) } else { Err("reverse^2 != id".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_name() {
        Prop::new("always fails").cases(10).run(|_, _| Err("nope".into()));
    }

    #[test]
    fn shrink_reports_small_size() {
        let result = std::panic::catch_unwind(|| {
            Prop::new("fails for all sizes").cases(5).max_size(64).run(|_, size| {
                if size >= 1 { Err(format!("size {size}")) } else { Ok(()) }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("size 1"), "shrunk message: {msg}");
    }

    #[test]
    fn nonflat_vec_has_two_values() {
        let mut rng = Pcg32::seeded(1);
        for _ in 0..100 {
            let v = vec_f32_nonflat(&mut rng, 4);
            assert!(v.iter().any(|&x| x != v[0]));
        }
    }
}
