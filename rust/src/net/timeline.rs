//! Cumulative simulated-time tracking across rounds + time-to-accuracy
//! queries (the paper's headline "time to reach a target accuracy" metric),
//! plus per-round scheduling records (who participated, who straggled, and
//! how long the server waited per device) so time-to-accuracy can be
//! compared across scheduling policies.

use super::RoundCost;

/// One round's scheduling outcome, recorded by
/// [`crate::sched::round::RoundScheduler`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedRecord {
    pub round: usize,
    /// devices whose Activations for *this* round made the close
    pub participants: Vec<usize>,
    /// straggler completions: devices whose Activations for an *earlier*
    /// round finally landed (and were processed) during this round
    pub stale: Vec<usize>,
    /// devices newly carried past this round's close (straggler timeout)
    pub stragglers: Vec<usize>,
    /// per-device fleet-clock seconds between round-open and arrival; for
    /// stragglers, open → close (the wait the server actually burned).
    /// 0.0 for devices that were not opened this round.
    pub wait_s: Vec<f64>,
}

impl SchedRecord {
    /// Longest per-device wait this round.
    pub fn max_wait_s(&self) -> f64 {
        self.wait_s.iter().copied().fold(0.0, f64::max)
    }
}

/// One device's cumulative scheduling history — see
/// [`Timeline::device_wait_profiles`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceWaitProfile {
    /// total fleet-clock seconds the server spent waiting on this device
    pub wait_s: f64,
    /// rounds this device was carried past a close as a straggler
    pub straggles: usize,
    /// rounds this device's Activations made the close
    pub participations: usize,
}

/// Accumulates per-round costs into a cumulative timeline.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    rounds: Vec<RoundCost>,
    cum_time: Vec<f64>,
    sched: Vec<Option<SchedRecord>>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, cost: RoundCost) {
        let prev = self.cum_time.last().copied().unwrap_or(0.0);
        self.cum_time.push(prev + cost.time_s);
        self.rounds.push(cost);
        self.sched.push(None);
    }

    /// Push a round with its scheduling outcome attached.
    pub fn push_with_sched(&mut self, cost: RoundCost, rec: SchedRecord) {
        self.push(cost);
        // push() just appended a slot; guard anyway rather than unwrap so a
        // future refactor of push() cannot turn this into a panic
        if let Some(slot) = self.sched.last_mut() {
            *slot = Some(rec);
        }
    }

    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    /// Cumulative simulated seconds after round `r` (0-based).
    pub fn time_after_round(&self, r: usize) -> f64 {
        self.cum_time[r]
    }

    pub fn total_time(&self) -> f64 {
        self.cum_time.last().copied().unwrap_or(0.0)
    }

    pub fn total_bytes_up(&self) -> usize {
        self.rounds.iter().map(|r| r.bytes_up).sum()
    }

    pub fn total_bytes_down(&self) -> usize {
        self.rounds.iter().map(|r| r.bytes_down).sum()
    }

    /// Total ModelSync bytes across the session (separate axis).
    pub fn total_bytes_sync(&self) -> usize {
        self.rounds.iter().map(|r| r.bytes_sync).sum()
    }

    pub fn round(&self, r: usize) -> &RoundCost {
        &self.rounds[r]
    }

    /// Scheduling record for round `r`, if the scheduler attached one.
    pub fn sched_record(&self, r: usize) -> Option<&SchedRecord> {
        self.sched.get(r).and_then(|s| s.as_ref())
    }

    /// All attached scheduling records, in round order.
    pub fn sched_records(&self) -> Vec<SchedRecord> {
        self.sched.iter().flatten().cloned().collect()
    }

    /// Total straggler carry-overs across the session.
    pub fn straggler_events(&self) -> usize {
        self.sched
            .iter()
            .flatten()
            .map(|s| s.stragglers.len())
            .sum()
    }

    /// Per-device cumulative scheduling profile across every recorded
    /// round: total fleet-clock seconds the server waited on the device,
    /// times it was carried as a straggler, and rounds it participated in.
    /// This is the seam a straggler-aware device-selection policy reads —
    /// `devices` is the fleet size (indices past any record's vectors stay
    /// zero; ids past `devices` are ignored).
    pub fn device_wait_profiles(&self, devices: usize) -> Vec<DeviceWaitProfile> {
        let mut out = vec![DeviceWaitProfile::default(); devices];
        for rec in self.sched.iter().flatten() {
            for (d, &w) in rec.wait_s.iter().enumerate() {
                if d < devices {
                    out[d].wait_s += w;
                }
            }
            for &d in &rec.participants {
                if d < devices {
                    out[d].participations += 1;
                }
            }
            for &d in &rec.stragglers {
                if d < devices {
                    out[d].straggles += 1;
                }
            }
        }
        out
    }

    /// Given (round, accuracy) observations, simulated time at which
    /// `target` accuracy was first reached (None if never).
    pub fn time_to_accuracy(&self, observations: &[(usize, f64)], target: f64)
                            -> Option<f64> {
        observations
            .iter()
            .find(|&&(_, acc)| acc >= target)
            .map(|&(round, _)| self.time_after_round(round.min(self.len() - 1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(t: f64, b: usize) -> RoundCost {
        RoundCost { bytes_up: b, bytes_down: b / 2, bytes_sync: b / 4, time_s: t }
    }

    #[test]
    fn cumulative_time() {
        let mut tl = Timeline::new();
        tl.push(cost(1.0, 100));
        tl.push(cost(2.0, 100));
        tl.push(cost(3.0, 100));
        assert!((tl.time_after_round(0) - 1.0).abs() < 1e-12);
        assert!((tl.time_after_round(2) - 6.0).abs() < 1e-12);
        assert!((tl.total_time() - 6.0).abs() < 1e-12);
        assert_eq!(tl.total_bytes_up(), 300);
        assert_eq!(tl.total_bytes_down(), 150);
        assert_eq!(tl.total_bytes_sync(), 75);
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut tl = Timeline::new();
        for _ in 0..10 {
            tl.push(cost(1.0, 1));
        }
        let obs = vec![(1, 0.3), (4, 0.55), (7, 0.7)];
        assert_eq!(tl.time_to_accuracy(&obs, 0.5), Some(5.0));
        assert_eq!(tl.time_to_accuracy(&obs, 0.9), None);
        assert_eq!(tl.time_to_accuracy(&obs, 0.2), Some(2.0));
    }

    #[test]
    fn sched_records_attach_to_rounds() {
        let mut tl = Timeline::new();
        tl.push(cost(1.0, 1)); // un-scheduled round (legacy push)
        tl.push_with_sched(
            cost(1.0, 1),
            SchedRecord {
                round: 1,
                participants: vec![0, 1],
                stale: vec![],
                stragglers: vec![2],
                wait_s: vec![0.1, 0.2, 0.5],
            },
        );
        tl.push_with_sched(
            cost(1.0, 1),
            SchedRecord { round: 2, stragglers: vec![2], ..Default::default() },
        );
        assert!(tl.sched_record(0).is_none());
        let r1 = tl.sched_record(1).unwrap();
        assert_eq!(r1.participants, vec![0, 1]);
        assert!((r1.max_wait_s() - 0.5).abs() < 1e-12);
        assert_eq!(tl.straggler_events(), 2);
        assert_eq!(tl.sched_records().len(), 2);
    }

    #[test]
    fn device_wait_profiles_accumulate() {
        let mut tl = Timeline::new();
        tl.push(cost(1.0, 1)); // un-scheduled round contributes nothing
        tl.push_with_sched(
            cost(1.0, 1),
            SchedRecord {
                round: 1,
                participants: vec![0, 1],
                stale: vec![],
                stragglers: vec![2],
                wait_s: vec![0.1, 0.2, 0.5],
            },
        );
        tl.push_with_sched(
            cost(1.0, 1),
            SchedRecord {
                round: 2,
                participants: vec![0, 2],
                stale: vec![],
                stragglers: vec![2],
                wait_s: vec![0.3, 0.0, 1.0],
            },
        );
        let p = tl.device_wait_profiles(3);
        assert_eq!(p.len(), 3);
        assert!((p[0].wait_s - 0.4).abs() < 1e-12);
        assert_eq!(p[0].participations, 2);
        assert_eq!(p[0].straggles, 0);
        assert!((p[1].wait_s - 0.2).abs() < 1e-12);
        assert_eq!(p[1].participations, 1);
        assert!((p[2].wait_s - 1.5).abs() < 1e-12);
        assert_eq!(p[2].straggles, 2);
        assert_eq!(p[2].participations, 1);
    }

    #[test]
    fn device_wait_profiles_ignore_out_of_range_ids() {
        let mut tl = Timeline::new();
        tl.push_with_sched(
            cost(1.0, 1),
            SchedRecord {
                round: 0,
                participants: vec![0, 9],
                stale: vec![],
                stragglers: vec![9],
                wait_s: vec![0.1, 0.2, 0.3, 0.4],
            },
        );
        let p = tl.device_wait_profiles(2);
        assert_eq!(p.len(), 2);
        assert!((p[0].wait_s - 0.1).abs() < 1e-12);
        assert!((p[1].wait_s - 0.2).abs() < 1e-12);
        assert_eq!(p[0].participations, 1);
        assert_eq!(p[1].participations, 0);
        assert_eq!(p[1].straggles, 0);
        // empty fleet degenerate
        assert!(tl.device_wait_profiles(0).is_empty());
    }
}
