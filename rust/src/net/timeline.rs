//! Cumulative simulated-time tracking across rounds + time-to-accuracy
//! queries (the paper's headline "time to reach a target accuracy" metric).

use super::RoundCost;

/// Accumulates per-round costs into a cumulative timeline.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    rounds: Vec<RoundCost>,
    cum_time: Vec<f64>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, cost: RoundCost) {
        let prev = self.cum_time.last().copied().unwrap_or(0.0);
        self.cum_time.push(prev + cost.time_s);
        self.rounds.push(cost);
    }

    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    /// Cumulative simulated seconds after round `r` (0-based).
    pub fn time_after_round(&self, r: usize) -> f64 {
        self.cum_time[r]
    }

    pub fn total_time(&self) -> f64 {
        self.cum_time.last().copied().unwrap_or(0.0)
    }

    pub fn total_bytes_up(&self) -> usize {
        self.rounds.iter().map(|r| r.bytes_up).sum()
    }

    pub fn total_bytes_down(&self) -> usize {
        self.rounds.iter().map(|r| r.bytes_down).sum()
    }

    pub fn round(&self, r: usize) -> &RoundCost {
        &self.rounds[r]
    }

    /// Given (round, accuracy) observations, simulated time at which
    /// `target` accuracy was first reached (None if never).
    pub fn time_to_accuracy(&self, observations: &[(usize, f64)], target: f64)
                            -> Option<f64> {
        observations
            .iter()
            .find(|&&(_, acc)| acc >= target)
            .map(|&(round, _)| self.time_after_round(round.min(self.len() - 1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(t: f64, b: usize) -> RoundCost {
        RoundCost { bytes_up: b, bytes_down: b / 2, time_s: t }
    }

    #[test]
    fn cumulative_time() {
        let mut tl = Timeline::new();
        tl.push(cost(1.0, 100));
        tl.push(cost(2.0, 100));
        tl.push(cost(3.0, 100));
        assert!((tl.time_after_round(0) - 1.0).abs() < 1e-12);
        assert!((tl.time_after_round(2) - 6.0).abs() < 1e-12);
        assert!((tl.total_time() - 6.0).abs() < 1e-12);
        assert_eq!(tl.total_bytes_up(), 300);
        assert_eq!(tl.total_bytes_down(), 150);
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut tl = Timeline::new();
        for _ in 0..10 {
            tl.push(cost(1.0, 1));
        }
        let obs = vec![(1, 0.3), (4, 0.55), (7, 0.7)];
        assert_eq!(tl.time_to_accuracy(&obs, 0.5), Some(5.0));
        assert_eq!(tl.time_to_accuracy(&obs, 0.9), None);
        assert_eq!(tl.time_to_accuracy(&obs, 0.2), Some(2.0));
    }
}
