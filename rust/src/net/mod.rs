//! Network + device-time simulator.
//!
//! The paper's headline metric is *time to target accuracy* on a fleet of
//! edge devices behind constrained links; what SL-ACC changes is the byte
//! volume of smashed-data transfers. This module converts the exact wire
//! bytes produced by the codecs into simulated wall-clock time:
//!
//!   round_time = max_d (client_fwd_d + up_d) + server_compute
//!              + max_d (down_d + client_bwd_d)
//!
//! (devices proceed in parallel, the server step is shared — the paper's
//! DDP emulation). Link and compute parameters default to a WiFi-class
//! edge deployment and are per-device configurable for heterogeneity
//! experiments.
//!
//! The byte counts fed in here are *measured*, not modeled: they are the
//! codec payload envelopes that [`crate::transport`] carries — over
//! in-process loopback queues in simulated runs, over real TCP sockets in
//! `slacc serve`/`slacc device` deployments. Both transports report
//! identical envelope bytes for the same config and seed; frame headers
//! and handshake/sync traffic are tracked separately per connection
//! ([`crate::transport::WireStats`]) and deliberately excluded from the
//! paper's "communication overhead" axis.

pub mod timeline;

/// Link + compute model for one device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceLink {
    /// uplink bandwidth, bits/s
    pub uplink_bps: f64,
    /// downlink bandwidth, bits/s
    pub downlink_bps: f64,
    /// one-way latency, seconds (paid once per transfer)
    pub latency_s: f64,
    /// client-side sub-model forward time per batch, seconds
    pub t_client_fwd: f64,
    /// client-side backward+update time per batch, seconds
    pub t_client_bwd: f64,
}

impl Default for DeviceLink {
    fn default() -> Self {
        // WiFi-class edge device: 50/50 Mbps, 10 ms RTT/2, tens of ms of
        // client compute for the 3-layer sub-model on a mobile SoC.
        DeviceLink {
            uplink_bps: 50e6,
            downlink_bps: 50e6,
            latency_s: 0.005,
            t_client_fwd: 0.030,
            t_client_bwd: 0.045,
        }
    }
}

impl DeviceLink {
    /// Time to push `bytes` up to the server.
    pub fn uplink_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.uplink_bps
    }

    /// Time to receive `bytes` from the server.
    pub fn downlink_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64 * 8.0) / self.downlink_bps
    }

    /// Scale compute+bandwidth for heterogeneous fleets (factor < 1 =
    /// slower device).
    pub fn scaled(&self, speed: f64) -> DeviceLink {
        assert!(speed > 0.0);
        DeviceLink {
            uplink_bps: self.uplink_bps * speed,
            downlink_bps: self.downlink_bps * speed,
            latency_s: self.latency_s,
            t_client_fwd: self.t_client_fwd / speed,
            t_client_bwd: self.t_client_bwd / speed,
        }
    }
}

/// Server-side compute model.
#[derive(Debug, Clone, Copy)]
pub struct ServerModel {
    /// server fwd+bwd+update time per device batch, seconds
    pub t_server_step: f64,
}

impl Default for ServerModel {
    fn default() -> Self {
        ServerModel { t_server_step: 0.008 }
    }
}

/// Whole-fleet network simulator.
#[derive(Debug, Clone)]
pub struct NetworkSim {
    pub links: Vec<DeviceLink>,
    pub server: ServerModel,
}

/// Byte/time accounting for one training round.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RoundCost {
    pub bytes_up: usize,
    pub bytes_down: usize,
    /// ModelSync (FedAvg) traffic, both directions. Accounted separately
    /// from the paper's smashed-data byte axis — the codecs shrink
    /// `bytes_up`/`bytes_down`; sync volume is a property of the model and
    /// the `--sync-codec` stream.
    pub bytes_sync: usize,
    pub time_s: f64,
}

impl RoundCost {
    /// Total smashed-data bytes this round, both directions (ModelSync
    /// traffic is deliberately excluded — see `bytes_sync`).
    pub fn total_bytes(&self) -> usize {
        self.bytes_up + self.bytes_down
    }
}

impl NetworkSim {
    pub fn homogeneous(devices: usize, link: DeviceLink, server: ServerModel) -> Self {
        NetworkSim { links: vec![link; devices], server }
    }

    /// Heterogeneous fleet: device d runs at `speeds[d]` × the base link.
    pub fn heterogeneous(base: DeviceLink, speeds: &[f64], server: ServerModel) -> Self {
        NetworkSim {
            links: speeds.iter().map(|&s| base.scaled(s)).collect(),
            server,
        }
    }

    pub fn devices(&self) -> usize {
        self.links.len()
    }

    /// Simulated time + bytes for one round given each device's uplink and
    /// downlink payload sizes. Devices compute/transmit in parallel; the
    /// server processes sequentially (one shared server model, as in SFL).
    /// This is the all-devices-active / no-sync special case of
    /// [`NetworkSim::round_cost_sched`].
    pub fn round_cost(&self, up_bytes: &[usize], down_bytes: &[usize]) -> RoundCost {
        let zeros = vec![0usize; self.links.len()];
        let active = vec![true; self.links.len()];
        self.round_cost_sched(up_bytes, down_bytes, &zeros, &zeros, &active)
    }

    /// Scheduler-aware round cost: only `active` devices (the ones that
    /// actually ran stages i–iv this round) contribute compute and transfer
    /// time, so a round that closed past the straggler timeout is *not*
    /// charged the straggler's slow link — that is the whole point of
    /// arrival-order scheduling. ModelSync pack bytes ride the same links
    /// (an extra up/down phase on aggregation rounds) but are accounted on
    /// their own `bytes_sync` axis.
    pub fn round_cost_sched(
        &self,
        up_bytes: &[usize],
        down_bytes: &[usize],
        sync_up: &[usize],
        sync_down: &[usize],
        active: &[bool],
    ) -> RoundCost {
        assert_eq!(up_bytes.len(), self.links.len());
        assert_eq!(down_bytes.len(), self.links.len());
        assert_eq!(sync_up.len(), self.links.len());
        assert_eq!(sync_down.len(), self.links.len());
        assert_eq!(active.len(), self.links.len());
        let act = |d: usize| active[d];
        let up_phase = self
            .links
            .iter()
            .enumerate()
            .filter(|&(d, _)| act(d))
            .map(|(d, l)| l.t_client_fwd + l.uplink_time(up_bytes[d]))
            .fold(0.0f64, f64::max);
        let active_n = active.iter().filter(|&&a| a).count();
        let server_phase = self.server.t_server_step * active_n as f64;
        let down_phase = self
            .links
            .iter()
            .enumerate()
            .filter(|&(d, _)| act(d))
            .map(|(d, l)| l.downlink_time(down_bytes[d]) + l.t_client_bwd)
            .fold(0.0f64, f64::max);
        // sync transfers are charged wherever their bytes landed, even for
        // a device that ran no training step this round (a carried
        // straggler finishing its ModelSync push still used the link)
        let sync_up_phase = self
            .links
            .iter()
            .enumerate()
            .filter(|&(d, _)| sync_up[d] > 0)
            .map(|(d, l)| l.uplink_time(sync_up[d]))
            .fold(0.0f64, f64::max);
        let sync_down_phase = self
            .links
            .iter()
            .enumerate()
            .filter(|&(d, _)| sync_down[d] > 0)
            .map(|(d, l)| l.downlink_time(sync_down[d]))
            .fold(0.0f64, f64::max);
        RoundCost {
            bytes_up: up_bytes.iter().sum(),
            bytes_down: down_bytes.iter().sum(),
            bytes_sync: sync_up.iter().sum::<usize>() + sync_down.iter().sum::<usize>(),
            time_s: up_phase + server_phase + down_phase + sync_up_phase + sync_down_phase,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let l = DeviceLink { uplink_bps: 8e6, latency_s: 0.01, ..Default::default() };
        // 1 MB over 8 Mbps = 1 s + 10 ms latency
        assert!((l.uplink_time(1_000_000) - 1.01).abs() < 1e-9);
    }

    #[test]
    fn fewer_bytes_less_time() {
        let sim = NetworkSim::homogeneous(3, DeviceLink::default(), ServerModel::default());
        let big = sim.round_cost(&[1_000_000; 3], &[1_000_000; 3]);
        let small = sim.round_cost(&[10_000; 3], &[10_000; 3]);
        assert!(small.time_s < big.time_s);
        assert_eq!(big.bytes_up, 3_000_000);
    }

    #[test]
    fn straggler_dominates() {
        let base = DeviceLink::default();
        let sim = NetworkSim::heterogeneous(base, &[1.0, 1.0, 0.1], ServerModel::default());
        let cost = sim.round_cost(&[100_000; 3], &[100_000; 3]);
        // the 10x-slower device alone would take:
        let slow = base.scaled(0.1);
        let expected_up = slow.t_client_fwd + slow.uplink_time(100_000);
        assert!(cost.time_s >= expected_up);
    }

    #[test]
    fn sched_cost_excludes_inactive_stragglers() {
        let base = DeviceLink::default();
        let sim = NetworkSim::heterogeneous(base, &[1.0, 1.0, 0.1], ServerModel::default());
        let zero = [0usize; 3];
        let all = sim.round_cost_sched(
            &[100_000; 3], &[100_000; 3], &zero, &zero, &[true; 3]);
        let partial = sim.round_cost_sched(
            &[100_000, 100_000, 0], &[100_000, 100_000, 0], &zero, &zero,
            &[true, true, false]);
        // dropping the 10x-slower straggler must shrink the round time
        assert!(partial.time_s < all.time_s);
        assert_eq!(partial.bytes_up, 200_000);
        assert_eq!(all.bytes_sync, 0);
    }

    #[test]
    fn sync_bytes_ride_their_own_axis() {
        let sim = NetworkSim::homogeneous(2, DeviceLink::default(), ServerModel::default());
        let zero = [0usize; 2];
        let no_sync = sim.round_cost_sched(
            &[1000; 2], &[1000; 2], &zero, &zero, &[true; 2]);
        let with_sync = sim.round_cost_sched(
            &[1000; 2], &[1000; 2], &[50_000; 2], &[50_000; 2], &[true; 2]);
        // smashed-data axis untouched; sync accounted separately but paid
        // in time
        assert_eq!(with_sync.bytes_up, no_sync.bytes_up);
        assert_eq!(with_sync.bytes_down, no_sync.bytes_down);
        assert_eq!(with_sync.bytes_sync, 200_000);
        assert!(with_sync.time_s > no_sync.time_s);
        // and matches the legacy formula when sync is zero and all active
        let legacy = sim.round_cost(&[1000; 2], &[1000; 2]);
        assert_eq!(no_sync, legacy);
    }

    #[test]
    fn server_time_scales_with_devices() {
        let s = ServerModel { t_server_step: 0.01 };
        let sim2 = NetworkSim::homogeneous(2, DeviceLink::default(), s);
        let sim8 = NetworkSim::homogeneous(8, DeviceLink::default(), s);
        let c2 = sim2.round_cost(&[0; 2], &[0; 2]);
        let c8 = sim8.round_cost(&[0; 8], &[0; 8]);
        assert!((c8.time_s - c2.time_s - 0.06).abs() < 1e-9);
    }
}
