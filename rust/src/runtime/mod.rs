//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the only bridge between the L3 coordinator and the L2/L1
//! compiled model. `Engine::load` parses the manifest, compiles every
//! `*.hlo.txt` once on the PJRT CPU client (`xla` crate 0.1.6 /
//! xla_extension 0.5.1), and `execute` runs a named artifact on host
//! tensors. HLO *text* is the interchange format — see aot.py and
//! /opt/xla-example/README.md for why serialized protos don't round-trip.
//!
//! Python is never involved here; after `make artifacts` the binary is
//! self-contained.

pub mod artifacts;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::tensor::Tensor;
use artifacts::{ArtifactSpec, DType, Manifest};

/// A host-side argument for `Engine::execute`.
#[derive(Debug, Clone, Copy)]
pub enum Arg<'a> {
    /// f32 tensor data with explicit dims
    F32(&'a [f32], &'a [usize]),
    /// i32 tensor data with explicit dims (labels)
    I32(&'a [i32], &'a [usize]),
    /// f32 scalar (learning rate)
    ScalarF32(f32),
}

impl Arg<'_> {
    fn dims(&self) -> Vec<usize> {
        match self {
            Arg::F32(_, d) | Arg::I32(_, d) => d.to_vec(),
            Arg::ScalarF32(_) => vec![],
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Arg::F32(..) | Arg::ScalarF32(_) => DType::F32,
            Arg::I32(..) => DType::I32,
        }
    }
}

/// Cumulative per-artifact execution statistics (perf accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

/// The PJRT execution engine: one compiled executable per artifact.
pub struct Engine {
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: HashMap<String, ExecStats>,
}

impl Engine {
    /// Load and compile every artifact under `dir` (e.g. `artifacts/ham`).
    pub fn load(dir: &Path) -> Result<Engine, String> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
        crate::log_info!(
            "runtime: PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        let mut executables = HashMap::new();
        for spec in &manifest.artifacts {
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().ok_or("non-utf8 artifact path")?,
            )
            .map_err(|e| format!("parse {}: {e}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format!("compile {}: {e}", spec.name))?;
            crate::log_debug!(
                "runtime: compiled {} in {:.2}s",
                spec.name,
                t0.elapsed().as_secs_f64()
            );
            executables.insert(spec.name.clone(), exe);
        }
        Ok(Engine { manifest, executables, stats: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name` with positional `args`; returns the output
    /// tuple as f32 host tensors (in the manifest's output order).
    pub fn execute(&mut self, name: &str, args: &[Arg<'_>]) -> Result<Vec<Tensor>, String> {
        let spec = self.manifest.artifact(name)?.clone();
        self.validate(&spec, args)?;
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| format!("artifact '{name}' not compiled"))?;

        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|a| Self::to_literal(a))
            .collect::<Result<_, _>>()?;

        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute {name}: {e}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name} result: {e}"))?;
        // aot.py lowers with return_tuple=True: root is always a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| format!("untuple {name}: {e}"))?;
        let elapsed = t0.elapsed().as_secs_f64();
        let st = self.stats.entry(name.to_string()).or_default();
        st.calls += 1;
        st.total_secs += elapsed;

        if parts.len() != spec.outputs.len() {
            return Err(format!(
                "{name}: {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, out)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| format!("{name}.{}: {e}", out.name))?;
                if data.len() != out.element_count() {
                    return Err(format!(
                        "{name}.{}: {} elements, expected {}",
                        out.name,
                        data.len(),
                        out.element_count()
                    ));
                }
                Ok(Tensor::new(out.dims.clone(), data))
            })
            .collect()
    }

    fn to_literal(arg: &Arg<'_>) -> Result<xla::Literal, String> {
        let lit = match arg {
            Arg::ScalarF32(x) => xla::Literal::scalar(*x),
            Arg::F32(data, dims) => {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| format!("reshape f32 arg: {e}"))?
            }
            Arg::I32(data, dims) => {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| format!("reshape i32 arg: {e}"))?
            }
        };
        Ok(lit)
    }

    fn validate(&self, spec: &ArtifactSpec, args: &[Arg<'_>]) -> Result<(), String> {
        if args.len() != spec.inputs.len() {
            return Err(format!(
                "{}: {} args, manifest says {}",
                spec.name,
                args.len(),
                spec.inputs.len()
            ));
        }
        for (i, (arg, inp)) in args.iter().zip(&spec.inputs).enumerate() {
            if arg.dims() != inp.dims {
                return Err(format!(
                    "{} arg {i} ({}): dims {:?}, expected {:?}",
                    spec.name,
                    inp.name,
                    arg.dims(),
                    inp.dims
                ));
            }
            if arg.dtype() != inp.dtype {
                return Err(format!(
                    "{} arg {i} ({}): dtype mismatch",
                    spec.name, inp.name
                ));
            }
            let len = match arg {
                Arg::F32(d, _) => d.len(),
                Arg::I32(d, _) => d.len(),
                Arg::ScalarF32(_) => 1,
            };
            if len != inp.element_count() {
                return Err(format!(
                    "{} arg {i} ({}): {len} elements, expected {}",
                    spec.name,
                    inp.name,
                    inp.element_count()
                ));
            }
        }
        Ok(())
    }

    /// Per-artifact cumulative execution stats.
    pub fn stats(&self) -> &HashMap<String, ExecStats> {
        &self.stats
    }
}
