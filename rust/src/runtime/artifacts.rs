//! AOT artifact manifest: the contract with `python/compile/aot.py`.
//!
//! `manifest.json` describes, per model config, every HLO artifact's I/O
//! signature (names/dims/dtypes in positional order), the flat parameter
//! layout of the client/server sub-models, and the cut-layer geometry. Any
//! schema change must be mirrored in aot.py (SCHEMA_VERSION guards drift).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

pub const SCHEMA_VERSION: usize = 1;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(format!("unknown dtype '{other}'")),
        }
    }
}

/// One input/output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One named parameter tensor in the flat init blob.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub dims: Vec<usize>,
    /// element offset into the f32 blob
    pub offset: usize,
    /// element count
    pub size: usize,
}

/// Cut-layer geometry (the smashed-data shape).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CutShape {
    pub b: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl CutShape {
    pub fn n_per_channel(&self) -> usize {
        self.b * self.h * self.w
    }

    pub fn dims(&self) -> Vec<usize> {
        vec![self.b, self.c, self.h, self.w]
    }
}

/// Parsed manifest for one model config directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config_name: String,
    pub in_ch: usize,
    pub classes: usize,
    pub batch: usize,
    pub img: usize,
    pub cut: CutShape,
    pub client_params: Vec<ParamSpec>,
    pub server_params: Vec<ParamSpec>,
    pub client_param_count: usize,
    pub server_param_count: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_io(j: &Json) -> Result<IoSpec, String> {
    let name = j.at(&["name"]).as_str().ok_or("io name")?.to_string();
    let dims = j
        .at(&["dims"])
        .as_arr()
        .ok_or("io dims")?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| "dim".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    let dtype = DType::parse(j.at(&["dtype"]).as_str().ok_or("io dtype")?)?;
    Ok(IoSpec { name, dims, dtype })
}

fn parse_param(j: &Json) -> Result<ParamSpec, String> {
    Ok(ParamSpec {
        name: j.at(&["name"]).as_str().ok_or("param name")?.to_string(),
        dims: j
            .at(&["dims"])
            .as_arr()
            .ok_or("param dims")?
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect(),
        offset: j.at(&["offset"]).as_usize().ok_or("param offset")?,
        size: j.at(&["size"]).as_usize().ok_or("param size")?,
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;

        let schema = j.at(&["schema"]).as_usize().ok_or("schema")?;
        if schema != SCHEMA_VERSION {
            return Err(format!(
                "manifest schema {schema} != supported {SCHEMA_VERSION}; \
                 re-run `make artifacts`"
            ));
        }
        let cfg = j.at(&["config"]);
        let cut = cfg.at(&["cut"]);
        let cut = CutShape {
            b: cut.at(&["b"]).as_usize().ok_or("cut.b")?,
            c: cut.at(&["c"]).as_usize().ok_or("cut.c")?,
            h: cut.at(&["h"]).as_usize().ok_or("cut.h")?,
            w: cut.at(&["w"]).as_usize().ok_or("cut.w")?,
        };

        let mut artifacts = Vec::new();
        if let Json::Obj(m) = j.at(&["artifacts"]) {
            for (name, a) in m {
                artifacts.push(ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.at(&["file"]).as_str().ok_or("artifact file")?),
                    inputs: a
                        .at(&["inputs"])
                        .as_arr()
                        .ok_or("inputs")?
                        .iter()
                        .map(parse_io)
                        .collect::<Result<Vec<_>, _>>()?,
                    outputs: a
                        .at(&["outputs"])
                        .as_arr()
                        .ok_or("outputs")?
                        .iter()
                        .map(parse_io)
                        .collect::<Result<Vec<_>, _>>()?,
                });
            }
        } else {
            return Err("manifest: artifacts is not an object".into());
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            config_name: cfg.at(&["name"]).as_str().ok_or("config.name")?.to_string(),
            in_ch: cfg.at(&["in_ch"]).as_usize().ok_or("in_ch")?,
            classes: cfg.at(&["classes"]).as_usize().ok_or("classes")?,
            batch: cfg.at(&["batch"]).as_usize().ok_or("batch")?,
            img: cfg.at(&["img"]).as_usize().ok_or("img")?,
            cut,
            client_params: j
                .at(&["client_params"])
                .as_arr()
                .ok_or("client_params")?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>, _>>()?,
            server_params: j
                .at(&["server_params"])
                .as_arr()
                .ok_or("server_params")?
                .iter()
                .map(parse_param)
                .collect::<Result<Vec<_>, _>>()?,
            client_param_count: j.at(&["client_param_count"]).as_usize().ok_or("cpc")?,
            server_param_count: j.at(&["server_param_count"]).as_usize().ok_or("spc")?,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest"))
    }

    /// Load a raw little-endian f32 blob (client_init.bin / server_init.bin)
    /// split into per-parameter tensors per the spec layout.
    pub fn load_param_blob(&self, file: &str, specs: &[ParamSpec])
                           -> Result<Vec<crate::tensor::Tensor>, String> {
        let path = self.dir.join(file);
        let bytes = std::fs::read(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let total: usize = specs.iter().map(|s| s.size).sum();
        if bytes.len() != total * 4 {
            return Err(format!(
                "{}: {} bytes, expected {}",
                path.display(),
                bytes.len(),
                total * 4
            ));
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(specs
            .iter()
            .map(|s| {
                crate::tensor::Tensor::new(
                    s.dims.clone(),
                    floats[s.offset..s.offset + s.size].to_vec(),
                )
            })
            .collect())
    }

    pub fn load_client_init(&self) -> Result<Vec<crate::tensor::Tensor>, String> {
        self.load_param_blob("client_init.bin", &self.client_params)
    }

    pub fn load_server_init(&self) -> Result<Vec<crate::tensor::Tensor>, String> {
        self.load_param_blob("server_init.bin", &self.server_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/ham");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config_name, "ham");
        assert_eq!(m.in_ch, 3);
        assert_eq!(m.classes, 7);
        assert_eq!(m.cut.c, 32);
        assert_eq!(m.cut.h, m.img / 2);
        for name in ["client_fwd", "server_step", "client_bwd", "eval_logits",
                     "entropy", "qdq"] {
            let a = m.artifact(name).unwrap();
            assert!(a.file.exists(), "{name} missing");
            assert!(!a.inputs.is_empty());
            assert!(!a.outputs.is_empty());
        }
    }

    #[test]
    fn param_blobs_match_specs() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let cp = m.load_client_init().unwrap();
        assert_eq!(cp.len(), m.client_params.len());
        let total: usize = cp.iter().map(|t| t.len()).sum();
        assert_eq!(total, m.client_param_count);
        // GN scales init to 1.0
        let scale_idx = m
            .client_params
            .iter()
            .position(|p| p.name == "stem.gn.scale")
            .unwrap();
        assert!(cp[scale_idx].data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
