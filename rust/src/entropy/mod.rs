//! ACII — Adaptive Channel Importance Identification (paper Sec. II-B).
//!
//! Combines the instantaneous per-channel entropy H_c^(t) (Eq. 1, computed
//! either by the AOT Pallas kernel or the host mirror in `shannon`) with the
//! historical mean H̃_c over the last k rounds (Eq. 2) using the balancing
//! hyperparameter α^(t) (Eq. 3, α = t/T by default).
//!
//! `AlphaSchedule` also exposes the fixed-α and pure-instant/pure-historical
//! modes used by the paper's own ablations (Figs. 3 and 4).

pub mod history;
pub mod shannon;

use history::EntropyHistory;

/// Balancing hyperparameter α^(t) policy (paper Eq. 3 + Fig. 4 ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlphaSchedule {
    /// Paper default: α = t/T (shift from instantaneous to historical).
    Adaptive,
    /// Fixed α ∈ [0,1]: 0 = pure instantaneous, 1 = pure historical.
    Fixed(f32),
}

impl AlphaSchedule {
    pub fn alpha(&self, round: usize, total_rounds: usize) -> f32 {
        match *self {
            AlphaSchedule::Adaptive => {
                if total_rounds == 0 {
                    0.0
                } else {
                    (round as f32 / total_rounds as f32).clamp(0.0, 1.0)
                }
            }
            AlphaSchedule::Fixed(a) => a.clamp(0.0, 1.0),
        }
    }
}

/// ACII state for one smashed-data stream (one per device per direction).
#[derive(Debug, Clone)]
pub struct Acii {
    history: EntropyHistory,
    schedule: AlphaSchedule,
    total_rounds: usize,
    round: usize,
}

impl Acii {
    /// `window` = k of Eq. 2; `total_rounds` = T of Eq. 3.
    pub fn new(channels: usize, window: usize, total_rounds: usize,
               schedule: AlphaSchedule) -> Self {
        Acii {
            history: EntropyHistory::new(channels, window),
            schedule,
            total_rounds,
            round: 0,
        }
    }

    pub fn channels(&self) -> usize {
        self.history.channels()
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn alpha(&self) -> f32 {
        self.schedule.alpha(self.round, self.total_rounds)
    }

    /// Blend instantaneous entropies with history (Eq. 2), then absorb the
    /// round into the history window and advance t. Returns blended H_c.
    ///
    /// Note the ordering matters and matches the paper: H̃_c is the average
    /// over the *past* k rounds (i = t-k .. t-1), excluding the current one.
    pub fn update(&mut self, instantaneous: &[f32]) -> Vec<f32> {
        assert_eq!(instantaneous.len(), self.channels());
        let alpha = self.alpha();
        let hist = self.history.historical(instantaneous);
        let blended: Vec<f32> = instantaneous
            .iter()
            .zip(&hist)
            .map(|(&hi, &hh)| (1.0 - alpha) * hi + alpha * hh)
            .collect();
        self.history.push(instantaneous);
        self.round += 1;
        blended
    }

    /// Blend from raw channel-major smashed data using the host entropy
    /// mirror (used when the PJRT kernel output isn't already available).
    pub fn update_from_data(&mut self, rows: &crate::tensor::ChannelMajor) -> Vec<f32> {
        let inst = shannon::entropies(rows);
        self.update(&inst)
    }

    /// Peek at the historical means without advancing the round.
    pub fn historical(&self, fallback: &[f32]) -> Vec<f32> {
        self.history.historical(fallback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_alpha_ramps() {
        let s = AlphaSchedule::Adaptive;
        assert_eq!(s.alpha(0, 100), 0.0);
        assert!((s.alpha(50, 100) - 0.5).abs() < 1e-6);
        assert_eq!(s.alpha(100, 100), 1.0);
        assert_eq!(s.alpha(150, 100), 1.0); // clamped past T
    }

    #[test]
    fn fixed_alpha_constant() {
        let s = AlphaSchedule::Fixed(0.3);
        assert_eq!(s.alpha(0, 10), 0.3);
        assert_eq!(s.alpha(9, 10), 0.3);
    }

    #[test]
    fn first_round_is_pure_instantaneous() {
        // alpha=0 at t=0 AND no history yet -> blended == instantaneous.
        let mut acii = Acii::new(2, 5, 100, AlphaSchedule::Adaptive);
        let out = acii.update(&[1.5, 2.5]);
        assert_eq!(out, vec![1.5, 2.5]);
    }

    #[test]
    fn pure_historical_ignores_current() {
        let mut acii = Acii::new(1, 10, 100, AlphaSchedule::Fixed(1.0));
        acii.update(&[2.0]); // history: [2.0] (first round falls back)
        let out = acii.update(&[100.0]); // alpha=1 -> pure history mean = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn pure_instantaneous_tracks_current() {
        let mut acii = Acii::new(1, 10, 100, AlphaSchedule::Fixed(0.0));
        acii.update(&[2.0]);
        let out = acii.update(&[100.0]);
        assert!((out[0] - 100.0).abs() < 1e-6);
    }

    #[test]
    fn blend_halfway() {
        let mut acii = Acii::new(1, 10, 2, AlphaSchedule::Adaptive);
        acii.update(&[4.0]); // t=0, alpha 0
        // t=1, alpha = 0.5, hist mean = 4.0, inst = 8.0 -> 6.0
        let out = acii.update(&[8.0]);
        assert!((out[0] - 6.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn history_excludes_current_round() {
        let mut acii = Acii::new(1, 3, 100, AlphaSchedule::Fixed(1.0));
        acii.update(&[1.0]);
        acii.update(&[3.0]);
        // history before this call: mean(1,3) = 2; current 99 must not count
        let out = acii.update(&[99.0]);
        assert!((out[0] - 2.0).abs() < 1e-6, "{out:?}");
    }

    #[test]
    fn update_from_data_matches_manual() {
        use crate::tensor::Tensor;
        let mut rng = crate::util::rng::Pcg32::seeded(3);
        let data: Vec<f32> = (0..2 * 4 * 3 * 3).map(|_| rng.next_gaussian()).collect();
        let cm = Tensor::new(vec![2, 4, 3, 3], data).to_channel_major();
        let inst = shannon::entropies(&cm);

        let mut a = Acii::new(4, 5, 10, AlphaSchedule::Adaptive);
        let mut b = Acii::new(4, 5, 10, AlphaSchedule::Adaptive);
        assert_eq!(a.update_from_data(&cm), b.update(&inst));
    }
}
