//! Rust mirror of the L1 Pallas entropy kernel (paper Eq. 1).
//!
//! The coordinator normally obtains instantaneous entropy from the AOT
//! `entropy.hlo.txt` artifact (the Pallas kernel). This module implements
//! the identical computation on the host for (a) parity tests against the
//! kernel, (b) codec unit tests that run without a PJRT client, and (c) the
//! downlink gradient path in configurations where the engine is bypassed.
//!
//! Pipeline per channel: min-max normalize to [0,1] → softmax over the N
//! elements → Shannon entropy −Σ p ln p. Must stay numerically in lockstep
//! with `python/compile/kernels/entropy_kernel.py` / `ref.py` (EPS, max
//! subtraction, natural log).

pub const EPS: f32 = 1e-8;

/// Shannon entropy (natural log) of one channel's elements.
pub fn channel_entropy(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty());
    let mut mn = xs[0];
    let mut mx = xs[0];
    for &x in xs {
        if x < mn {
            mn = x;
        }
        if x > mx {
            mx = x;
        }
    }
    let denom = (mx - mn).max(EPS);

    // z in [0,1]; stable softmax: subtract max(z).
    // max(z) is (mx-mn)/denom which is 1 unless the channel is flat (then 0).
    let zmax = (mx - mn) / denom;
    let mut sum = 0.0f64;
    // two-pass: exp sum, then entropy via H = ln S - (1/S) Σ e_i s_i
    // where s_i = z_i - zmax and e_i = exp(s_i).
    let mut dot = 0.0f64; // Σ e_i * s_i
    for &x in xs {
        let z = (x - mn) / denom;
        let s = (z - zmax) as f64;
        let e = s.exp();
        sum += e;
        dot += e * s;
    }
    // H = -Σ p ln p,  p_i = e_i / S,  ln p_i = s_i - ln S
    // H = -Σ (e_i/S)(s_i - ln S) = ln S - dot/S
    (sum.ln() - dot / sum) as f32
}

/// Per-channel entropies of channel-major rows.
pub fn entropies(rows: &crate::tensor::ChannelMajor) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows.channels);
    entropies_into(rows, &mut out);
    out
}

/// [`entropies`] into a caller-owned buffer: `out` is cleared and refilled,
/// so a warmed buffer makes the steady-state path allocation-free (the
/// per-channel kernel itself never allocates — min/max are fused into its
/// first pass, and the exp sums stream in the second; the softmax is never
/// materialized). Bit-exact with [`channel_entropy`] per channel; the
/// counting-allocator audit in `benches/codecs.rs` pins the zero-alloc
/// contract.
pub fn entropies_into(rows: &crate::tensor::ChannelMajor, out: &mut Vec<f32>) {
    out.clear();
    out.extend((0..rows.channels).map(|c| channel_entropy(rows.channel(c))));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::prop::{vec_f32, Prop};
    use crate::util::rng::Pcg32;

    /// Literal transcription of ref.py (softmax materialized) for testing.
    fn entropy_naive(xs: &[f32]) -> f32 {
        let mn = xs.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let denom = (mx - mn).max(EPS);
        let z: Vec<f64> = xs.iter().map(|&x| ((x - mn) / denom) as f64).collect();
        let zmax = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = z.iter().map(|&v| (v - zmax).exp()).collect();
        let s: f64 = e.iter().sum();
        -e.iter().map(|&ei| (ei / s) * (ei / s).ln()).sum::<f64>() as f32
    }

    #[test]
    fn matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for len in [2usize, 7, 64, 1000] {
            let xs: Vec<f32> = (0..len).map(|_| rng.next_gaussian() * 3.0).collect();
            let fast = channel_entropy(&xs);
            let slow = entropy_naive(&xs);
            assert!((fast - slow).abs() < 1e-4, "len {len}: {fast} vs {slow}");
        }
    }

    #[test]
    fn flat_channel_is_ln_n() {
        let xs = vec![4.2f32; 100];
        let h = channel_entropy(&xs);
        assert!((h - (100f32).ln()).abs() < 1e-4, "{h}");
    }

    #[test]
    fn peaked_below_flat() {
        let mut xs = vec![0.0f32; 256];
        xs[0] = 1000.0;
        assert!(channel_entropy(&xs) < channel_entropy(&vec![0.0f32; 256]));
    }

    #[test]
    fn bounds_property() {
        Prop::new("0 <= H <= ln N").cases(200).max_size(512).run(|rng, size| {
            let n = (size + 1).max(2);
            let xs = vec_f32(rng, n);
            let h = channel_entropy(&xs);
            if h < -1e-4 {
                return Err(format!("H={h} < 0"));
            }
            if h > (n as f32).ln() + 1e-3 {
                return Err(format!("H={h} > ln {n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn shift_scale_invariance_property() {
        Prop::new("entropy invariant to affine + scale > 0")
            .cases(100)
            .max_size(256)
            .run(|rng, size| {
                let n = (size + 1).max(2);
                let xs = vec_f32(rng, n);
                let shift = rng.range_f32(-100.0, 100.0);
                let scale = rng.range_f32(0.1, 10.0);
                let ys: Vec<f32> = xs.iter().map(|&x| x * scale + shift).collect();
                let (h1, h2) = (channel_entropy(&xs), channel_entropy(&ys));
                if (h1 - h2).abs() > 2e-3 {
                    return Err(format!("{h1} vs {h2}"));
                }
                Ok(())
            });
    }

    #[test]
    fn entropies_match_per_channel() {
        let mut rng = Pcg32::seeded(5);
        let data: Vec<f32> = (0..2 * 3 * 4 * 4).map(|_| rng.next_gaussian()).collect();
        let t = Tensor::new(vec![2, 3, 4, 4], data);
        let cm = t.to_channel_major();
        let hs = entropies(&cm);
        assert_eq!(hs.len(), 3);
        for c in 0..3 {
            assert_eq!(hs[c], channel_entropy(cm.channel(c)));
        }
    }

    #[test]
    fn entropies_into_is_bit_exact_and_reusable() {
        let mut rng = Pcg32::seeded(9);
        let mut scratch = Vec::new();
        // reuse ONE buffer across differently-shaped inputs: each call must
        // clear stale contents and match the allocating path bit for bit
        for (b, c, hw) in [(2usize, 5usize, 3usize), (4, 2, 4), (1, 8, 2)] {
            let data: Vec<f32> =
                (0..b * c * hw * hw).map(|_| rng.next_gaussian()).collect();
            let cm = Tensor::new(vec![b, c, hw, hw], data).to_channel_major();
            entropies_into(&cm, &mut scratch);
            let fresh = entropies(&cm);
            assert_eq!(scratch.len(), c);
            for (a, b) in scratch.iter().zip(&fresh) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
