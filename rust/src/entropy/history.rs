//! Per-channel entropy history: the H̃_c term of ACII (paper Eq. 2).
//!
//! Historical entropy is the mean of each channel's instantaneous entropy
//! over the last `k` rounds, maintained as a ring buffer with running sums
//! so a round update is O(C) regardless of window size.

#[derive(Debug, Clone)]
pub struct EntropyHistory {
    window: usize,
    channels: usize,
    /// ring[r][c]: entropy of channel c at slot r
    ring: Vec<Vec<f32>>,
    /// running per-channel sums over the ring
    sums: Vec<f64>,
    /// number of rounds pushed so far (saturates reporting at `window`)
    filled: usize,
    /// next slot to overwrite
    head: usize,
}

impl EntropyHistory {
    pub fn new(channels: usize, window: usize) -> Self {
        assert!(window >= 1, "history window must be >= 1");
        EntropyHistory {
            window,
            channels,
            ring: vec![vec![0.0; channels]; window],
            sums: vec![0.0; channels],
            filled: 0,
            head: 0,
        }
    }

    pub fn channels(&self) -> usize {
        self.channels
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Rounds currently contributing to the mean (<= window).
    pub fn depth(&self) -> usize {
        self.filled.min(self.window)
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    /// Record one round of instantaneous entropies.
    pub fn push(&mut self, inst: &[f32]) {
        assert_eq!(inst.len(), self.channels);
        let slot = &mut self.ring[self.head];
        for c in 0..self.channels {
            if self.filled >= self.window {
                self.sums[c] -= slot[c] as f64;
            }
            slot[c] = inst[c];
            self.sums[c] += inst[c] as f64;
        }
        self.head = (self.head + 1) % self.window;
        self.filled += 1;
    }

    /// Historical entropy H̃_c: mean over the stored rounds. Falls back to
    /// the provided instantaneous value when no history exists yet.
    pub fn historical(&self, fallback: &[f32]) -> Vec<f32> {
        let d = self.depth();
        if d == 0 {
            return fallback.to_vec();
        }
        self.sums.iter().map(|&s| (s / d as f64) as f32).collect()
    }

    /// Historical entropy of a single channel (None if no history).
    pub fn historical_channel(&self, c: usize) -> Option<f32> {
        let d = self.depth();
        if d == 0 {
            None
        } else {
            Some((self.sums[c] / d as f64) as f32)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_uses_fallback() {
        let h = EntropyHistory::new(3, 4);
        assert_eq!(h.historical(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
        assert!(h.is_empty());
    }

    #[test]
    fn mean_over_partial_window() {
        let mut h = EntropyHistory::new(2, 5);
        h.push(&[1.0, 10.0]);
        h.push(&[3.0, 20.0]);
        let m = h.historical(&[0.0, 0.0]);
        assert!((m[0] - 2.0).abs() < 1e-6);
        assert!((m[1] - 15.0).abs() < 1e-6);
        assert_eq!(h.depth(), 2);
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut h = EntropyHistory::new(1, 3);
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            h.push(&[v]);
        }
        // window holds [2, 3, 4]
        assert!((h.historical(&[0.0])[0] - 3.0).abs() < 1e-6);
        assert_eq!(h.depth(), 3);
    }

    #[test]
    fn running_sum_matches_recompute_long_run() {
        let mut h = EntropyHistory::new(4, 7);
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        let mut log: Vec<Vec<f32>> = Vec::new();
        for _ in 0..50 {
            let row: Vec<f32> = (0..4).map(|_| rng.next_f32() * 5.0).collect();
            h.push(&row);
            log.push(row);
        }
        let tail = &log[log.len() - 7..];
        for c in 0..4 {
            let want: f32 = tail.iter().map(|r| r[c]).sum::<f32>() / 7.0;
            let got = h.historical_channel(c).unwrap();
            assert!((want - got).abs() < 1e-4, "c={c}: {want} vs {got}");
        }
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut h = EntropyHistory::new(3, 2);
        h.push(&[1.0, 2.0]);
    }
}
