//! Adaptive runtime renegotiation: the telemetry-driven control loop that
//! retunes per-stream codecs mid-session (`--adapt`, proto v5).
//!
//! The negotiated [`StreamSpecs`] table used to be frozen at the Hello
//! handshake; this module turns it into a *runtime* quantity. At every
//! closed round the server consults a [`Controller`] with that round's
//! telemetry ([`RoundObs`]: per-stream compressed/raw byte ratios, the
//! windowed `slacc_entropy_{mean,var}_milli` drift gauges, and the
//! scheduler's wait profile). When the controller decides to retune, the
//! server:
//!
//! 1. re-negotiates the table ([`retuned_specs`]: the uplink steps to the
//!    chosen spec, the downlink follows unless it is the lossless identity
//!    stream, the sync streams never change — they are stateful and
//!    session-long on both ends),
//! 2. pushes a [`Message::SpecUpdate`] frame (new table + FNV digest +
//!    activation round) to every device at the round boundary, a full
//!    round ([`ACTIVATION_LEAD`]) before activation,
//! 3. collects one [`Message::SpecUpdateAck`] per device — a device that
//!    sends an activation-round frame without having acked is a protocol
//!    error, same discipline as the Hello digest cross-check — and
//! 4. swaps its own decode/encode twins at the agreed round via
//!    [`SpecEpochs`]: per-round epoch lookup, so a carried straggler
//!    finishing a stale round is served under the *old* table while
//!    current-round traffic already runs the new one.
//!
//! Devices mirror step 4 exactly: the fresh [`DeviceStreams`] built at the
//! first `RoundOpen >= activate_round` are seed-identical twins of the
//! server's new epoch (stream seeds are a pure function of session seed +
//! device + direction), so wire bytes stay byte-for-byte reproducible
//! across loopback and TCP through a transition.
//!
//! Two controller families parse from the `--adapt` directive:
//!
//! * `at:R=<spec>[,R=<spec>...]` — [`ForcedScheduleController`]: an
//!   explicit transition schedule (activate `<spec>` at round `R`).
//!   Transport-invariant, so it is what the parity tests and mock
//!   sessions drive.
//! * `ladder:<spec1>,<spec2>[,...][;cooldown=N][;up-below=X][;down-above=Y]`
//!   — [`EntropyBudgetController`]: steps the uplink *up* the rung list
//!   (more aggressive compression) while the windowed channel-entropy
//!   variance sits at or below `up-below` milli-bits, and back *down*
//!   when it reaches `down-above`. The gap between the two thresholds is
//!   the hysteresis band and `cooldown` rounds must pass between
//!   transitions, so the controller never flip-flops on a noisy gauge.

use crate::codecs::stream::{StreamSet, StreamSpec, StreamSpecs};
use crate::codecs::CodecError;

/// How many rounds ahead of the decision boundary a transition activates:
/// a decision at the close of round `c` activates at `c + ACTIVATION_LEAD`.
/// The scheduler opens at most one round past the last close, so the
/// SpecUpdate pushed at close of `c` always precedes the activation
/// round's RoundOpen on every device's (FIFO) connection — the ack can be
/// collected before the first frame of the activation round without ever
/// stalling the pipeline.
pub const ACTIVATION_LEAD: usize = 2;

/// One closed round's telemetry, as the controller sees it. Assembled
/// server-side from the round record and the live obs registry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundObs {
    /// uplink compression ratio this round (raw f32 bytes / wire bytes)
    pub ratio_up: f64,
    /// downlink compression ratio this round
    pub ratio_down: f64,
    /// windowed mean of the uplink channel entropy (`slacc_entropy_mean_milli`)
    pub entropy_mean_milli: i64,
    /// windowed variance of the uplink channel entropy (`slacc_entropy_var_milli`)
    pub entropy_var_milli: i64,
    /// the slowest device's wait this round (timeline wait profile)
    pub max_wait_s: f64,
}

/// A renegotiation policy: consulted once per closed round (only while no
/// earlier transition is still in flight) and answers with the uplink spec
/// to step to, or `None` to hold.
pub trait Controller {
    fn decide(&mut self, round: usize, obs: &RoundObs) -> Option<String>;

    /// Short name for logs and the bench report.
    fn label(&self) -> &'static str;
}

/// Re-negotiate the full table for a new uplink spec: the downlink follows
/// the uplink (the paper compresses both data directions) unless the
/// session runs it as the lossless identity stream, and the sync spec is
/// carried over verbatim — sync codecs are stateful and session-long, so
/// a transition never touches them.
pub fn retuned_specs(current: &StreamSpecs, uplink: &str) -> Result<StreamSpecs, CodecError> {
    let downlink = if current.downlink.as_str() == "identity" {
        "identity"
    } else {
        uplink
    };
    StreamSpecs::parse(uplink, downlink, current.sync.as_str())
}

/// A parsed `--adapt` directive.
#[derive(Debug, Clone, PartialEq)]
pub enum AdaptPlan {
    /// `at:R=<spec>,...` — explicit activation rounds.
    Forced(Vec<(usize, String)>),
    /// `ladder:<spec>,...` — entropy-budget rung walking.
    Ladder {
        rungs: Vec<String>,
        cooldown: usize,
        up_below: i64,
        down_above: i64,
    },
}

/// Canonicalize one spec token through the registry grammar (so `none`
/// and `identity` compare equal everywhere downstream).
fn canon_spec(s: &str) -> Result<String, String> {
    StreamSpec::parse(s)
        .map(|sp| sp.as_str().to_string())
        .map_err(|e| format!("--adapt: invalid spec '{s}': {e}"))
}

impl AdaptPlan {
    /// Parse an `--adapt` directive. Grammar:
    ///
    /// * `at:R=<spec>[,R=<spec>...]` — rounds strictly increasing, each
    ///   `>= 2` (a transition needs [`ACTIVATION_LEAD`] rounds of runway).
    /// * `ladder:<spec1>,<spec2>[,...]` with optional `;cooldown=N`,
    ///   `;up-below=X`, `;down-above=Y` suffixes (milli-bit thresholds,
    ///   `up-below < down-above`).
    pub fn parse(s: &str) -> Result<AdaptPlan, String> {
        if let Some(body) = s.strip_prefix("at:") {
            let mut entries = Vec::new();
            for part in body.split(',') {
                let (round, spec) = part.split_once('=').ok_or_else(|| {
                    format!("--adapt at: entry '{part}' is not R=<spec>")
                })?;
                let round: usize = round.trim().parse().map_err(|_| {
                    format!("--adapt at: '{round}' is not a round number")
                })?;
                if round < ACTIVATION_LEAD {
                    return Err(format!(
                        "--adapt at: round {round} is too early (a transition \
                         activates no earlier than round {ACTIVATION_LEAD})"
                    ));
                }
                if let Some(&(prev, _)) = entries.last() {
                    if round <= prev {
                        return Err(format!(
                            "--adapt at: rounds must be strictly increasing \
                             ({prev} then {round})"
                        ));
                    }
                }
                entries.push((round, canon_spec(spec.trim())?));
            }
            if entries.is_empty() {
                return Err("--adapt at: needs at least one R=<spec> entry".into());
            }
            return Ok(AdaptPlan::Forced(entries));
        }
        if let Some(body) = s.strip_prefix("ladder:") {
            let mut parts = body.split(';');
            let rungs: Vec<String> = parts
                .next()
                .unwrap_or("")
                .split(',')
                .filter(|r| !r.trim().is_empty())
                .map(|r| canon_spec(r.trim()))
                .collect::<Result<_, _>>()?;
            if rungs.len() < 2 {
                return Err(
                    "--adapt ladder: needs at least two rungs to step between".into()
                );
            }
            let mut cooldown = 8usize;
            let mut up_below = 150i64;
            let mut down_above = 600i64;
            for opt in parts {
                let (key, val) = opt.split_once('=').ok_or_else(|| {
                    format!("--adapt ladder: option '{opt}' is not key=value")
                })?;
                match key.trim() {
                    "cooldown" => {
                        cooldown = val.trim().parse().map_err(|_| {
                            format!("--adapt ladder: cooldown '{val}' is not a number")
                        })?;
                        if cooldown == 0 {
                            return Err("--adapt ladder: cooldown must be >= 1".into());
                        }
                    }
                    "up-below" => {
                        up_below = val.trim().parse().map_err(|_| {
                            format!("--adapt ladder: up-below '{val}' is not a number")
                        })?;
                    }
                    "down-above" => {
                        down_above = val.trim().parse().map_err(|_| {
                            format!("--adapt ladder: down-above '{val}' is not a number")
                        })?;
                    }
                    other => {
                        return Err(format!(
                            "--adapt ladder: unknown option '{other}' \
                             (cooldown, up-below, down-above)"
                        ))
                    }
                }
            }
            if up_below >= down_above {
                return Err(format!(
                    "--adapt ladder: up-below ({up_below}) must be strictly below \
                     down-above ({down_above}) — the gap is the hysteresis band"
                ));
            }
            return Ok(AdaptPlan::Ladder { rungs, cooldown, up_below, down_above });
        }
        Err(format!(
            "--adapt: unknown directive '{s}' (expected at:R=<spec>,... or \
             ladder:<spec>,<spec>,...)"
        ))
    }

    /// Build the controller this plan describes. `initial_uplink` is the
    /// session's handshake-time uplink spec (canonical form); a ladder must
    /// contain it so the controller knows its starting rung.
    pub fn controller(&self, initial_uplink: &str) -> Result<Box<dyn Controller>, String> {
        match self {
            AdaptPlan::Forced(entries) => Ok(Box::new(ForcedScheduleController {
                entries: entries.clone(),
                next: 0,
            })),
            AdaptPlan::Ladder { rungs, cooldown, up_below, down_above } => {
                let pos = rungs
                    .iter()
                    .position(|r| r == initial_uplink)
                    .ok_or_else(|| {
                        format!(
                            "--adapt ladder: the session's uplink spec \
                             '{initial_uplink}' is not one of the rungs \
                             ({}) — the ladder must include the starting spec",
                            rungs.join(",")
                        )
                    })?;
                Ok(Box::new(EntropyBudgetController {
                    rungs: rungs.clone(),
                    pos,
                    cooldown: *cooldown,
                    up_below: *up_below,
                    down_above: *down_above,
                    since_last: 0,
                }))
            }
        }
    }
}

/// Plays back an explicit `at:R=<spec>` schedule. An entry fires at the
/// first consulted boundary whose activation round reaches it — "at round
/// R, or the first boundary after R once any earlier transition has
/// settled" — so back-to-back entries are never silently dropped.
pub struct ForcedScheduleController {
    entries: Vec<(usize, String)>,
    next: usize,
}

impl Controller for ForcedScheduleController {
    fn decide(&mut self, round: usize, _obs: &RoundObs) -> Option<String> {
        let (at, spec) = self.entries.get(self.next)?;
        if *at <= round + ACTIVATION_LEAD {
            self.next += 1;
            return Some(spec.clone());
        }
        None
    }

    fn label(&self) -> &'static str {
        "forced-schedule"
    }
}

/// The default telemetry-driven policy: walk an ordered rung list (least →
/// most aggressive compression) on the windowed uplink channel-entropy
/// variance. A stable activation distribution (variance at or below
/// `up_below` milli-bits) means harder compression is safe; a drifting one
/// (at or above `down_above`) steps back toward fidelity. In between the
/// controller holds — the dead band plus the `cooldown` round count is the
/// anti-flip-flop discipline.
pub struct EntropyBudgetController {
    rungs: Vec<String>,
    pos: usize,
    cooldown: usize,
    up_below: i64,
    down_above: i64,
    since_last: usize,
}

impl Controller for EntropyBudgetController {
    fn decide(&mut self, _round: usize, obs: &RoundObs) -> Option<String> {
        self.since_last += 1;
        if self.since_last < self.cooldown {
            return None;
        }
        if obs.entropy_var_milli <= self.up_below && self.pos + 1 < self.rungs.len() {
            self.pos += 1;
            self.since_last = 0;
            return Some(self.rungs[self.pos].clone());
        }
        if obs.entropy_var_milli >= self.down_above && self.pos > 0 {
            self.pos -= 1;
            self.since_last = 0;
            return Some(self.rungs[self.pos].clone());
        }
        None
    }

    fn label(&self) -> &'static str {
        "entropy-budget"
    }
}

/// One server-side stream-table epoch: `set` serves every round from
/// `from_round` until the next epoch begins.
struct Epoch {
    from_round: usize,
    set: StreamSet,
}

/// The server's per-round view of the stream table: epoch 0 is the
/// handshake-negotiated set, later epochs are pushed by accepted
/// transitions. Lookups are by round, so in-flight frames of a stale round
/// (carried stragglers) decode/encode under the table that round ran with.
pub struct SpecEpochs {
    epochs: Vec<Epoch>,
}

impl SpecEpochs {
    /// Wrap the handshake-negotiated set as epoch 0 (active from round 0).
    pub fn new(initial: StreamSet) -> SpecEpochs {
        SpecEpochs { epochs: vec![Epoch { from_round: 0, set: initial }] }
    }

    /// Devices served (identical across epochs).
    pub fn devices(&self) -> usize {
        self.epochs[0].set.devices()
    }

    /// The handshake-time spec table (epoch 0's).
    pub fn initial_specs(&self) -> &StreamSpecs {
        self.epochs[0].set.specs()
    }

    /// The most recently negotiated table (the last epoch's, whether or
    /// not its activation round has been reached).
    pub fn current_specs(&self) -> &StreamSpecs {
        self.epochs.last().expect("never empty").set.specs()
    }

    /// The most recently negotiated stream set.
    pub fn current(&self) -> &StreamSet {
        &self.epochs.last().expect("never empty").set
    }

    /// Number of epochs negotiated so far (1 = never retuned).
    pub fn len(&self) -> usize {
        self.epochs.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// The stream set serving `round`: the last epoch whose activation
    /// round is `<= round`.
    pub fn for_round(&mut self, round: usize) -> &mut StreamSet {
        let i = self
            .epochs
            .iter()
            .rposition(|e| e.from_round <= round)
            .expect("epoch 0 starts at round 0");
        &mut self.epochs[i].set
    }

    /// The set owning the session-long sync-stream instances. Sync codecs
    /// are stateful across the whole session and never renegotiated, so
    /// they always live in epoch 0 regardless of data-stream transitions.
    pub fn sync_set(&mut self) -> &mut StreamSet {
        &mut self.epochs[0].set
    }

    /// The spec table active for `round`, rendered for the round CSV.
    pub fn active_table(&self, round: usize) -> String {
        let i = self
            .epochs
            .iter()
            .rposition(|e| e.from_round <= round)
            .expect("epoch 0 starts at round 0");
        self.epochs[i].set.specs().table()
    }

    /// Install a new epoch activating at `from_round` (strictly after the
    /// last epoch's activation round).
    pub fn push(&mut self, from_round: usize, set: StreamSet) {
        debug_assert!(
            from_round > self.epochs.last().expect("never empty").from_round,
            "epochs must activate in increasing round order"
        );
        self.epochs.push(Epoch { from_round, set });
    }

    /// Rebuild device `d`'s codec instances in **every** epoch from their
    /// session-fixed seeds. A re-admitted device restarts its streams from
    /// a fresh process, so the server's twins must be reset in the sync set
    /// (epoch 0) and any later data-stream epoch alike — otherwise the
    /// first post-catchup frame would decode against stale stream state.
    pub fn rebuild_device(&mut self, d: usize) -> Result<(), CodecError> {
        for e in &mut self.epochs {
            e.set.rebuild_device(d)?;
        }
        Ok(())
    }
}

/// A pushed-but-unsettled transition: the server holds new epochs here
/// until every device has acked.
pub struct PendingUpdate {
    pub activate: usize,
    pub fp: u64,
    /// per-local-slot "ack still owed" flags
    pub unacked: Vec<bool>,
}

impl PendingUpdate {
    pub fn fully_acked(&self) -> bool {
        self.unacked.iter().all(|&u| !u)
    }
}

/// The server's adaptation state: the controller plus the in-flight
/// transition (at most one — the controller is not consulted again until
/// the previous push is fully acked).
pub struct AdaptState {
    pub controller: Box<dyn Controller>,
    pub pending: Option<PendingUpdate>,
}

impl AdaptState {
    /// Parse an `--adapt` directive and bind it to the session's initial
    /// spec table.
    pub fn from_directive(directive: &str, initial: &StreamSpecs) -> Result<AdaptState, String> {
        let plan = AdaptPlan::parse(directive)?;
        let controller = plan.controller(initial.uplink.as_str())?;
        Ok(AdaptState { controller, pending: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::slacc::SlAccConfig;
    use crate::codecs::stream::SessionStreamCfg;

    fn obs(var: i64) -> RoundObs {
        RoundObs {
            ratio_up: 4.0,
            ratio_down: 4.0,
            entropy_mean_milli: 2500,
            entropy_var_milli: var,
            max_wait_s: 0.0,
        }
    }

    #[test]
    fn parse_forced_schedule() {
        let p = AdaptPlan::parse("at:4=uniform4,9=none").unwrap();
        // specs canonicalize (none -> identity)
        assert_eq!(
            p,
            AdaptPlan::Forced(vec![(4, "uniform4".into()), (9, "identity".into())])
        );
        assert!(AdaptPlan::parse("at:").is_err());
        assert!(AdaptPlan::parse("at:4").is_err(), "missing =spec");
        assert!(AdaptPlan::parse("at:1=uniform4").is_err(), "too early");
        assert!(AdaptPlan::parse("at:5=uniform4,5=uniform2").is_err(), "not increasing");
        assert!(AdaptPlan::parse("at:5=bogus").is_err(), "unknown spec");
    }

    #[test]
    fn parse_ladder() {
        let p = AdaptPlan::parse("ladder:uniform8,uniform4").unwrap();
        assert_eq!(
            p,
            AdaptPlan::Ladder {
                rungs: vec!["uniform8".into(), "uniform4".into()],
                cooldown: 8,
                up_below: 150,
                down_above: 600,
            }
        );
        let p =
            AdaptPlan::parse("ladder:slacc,uniform4;cooldown=3;up-below=50;down-above=90")
                .unwrap();
        assert_eq!(
            p,
            AdaptPlan::Ladder {
                rungs: vec!["slacc".into(), "uniform4".into()],
                cooldown: 3,
                up_below: 50,
                down_above: 90,
            }
        );
        assert!(AdaptPlan::parse("ladder:uniform8").is_err(), "one rung");
        assert!(AdaptPlan::parse("ladder:uniform8,bogus").is_err());
        assert!(AdaptPlan::parse("ladder:a8,a4;cooldown=0").is_err());
        assert!(
            AdaptPlan::parse("ladder:uniform8,uniform4;up-below=600;down-above=600")
                .is_err(),
            "no hysteresis band"
        );
        assert!(AdaptPlan::parse("ladder:uniform8,uniform4;wat=1").is_err());
        assert!(AdaptPlan::parse("nonsense").is_err());
    }

    #[test]
    fn forced_controller_fires_in_order_and_carries_late_entries() {
        let plan = AdaptPlan::parse("at:4=uniform4,5=uniform2").unwrap();
        let mut c = plan.controller("uniform8").unwrap();
        assert_eq!(c.decide(0, &obs(0)), None, "round 4 needs close of >= 2");
        assert_eq!(c.decide(1, &obs(0)), None);
        assert_eq!(c.decide(2, &obs(0)), Some("uniform4".into()));
        // entry 5 wanted the close of round 3, but the first transition was
        // still settling; it fires at the next consulted boundary instead
        // of being dropped
        assert_eq!(c.decide(4, &obs(0)), Some("uniform2".into()));
        assert_eq!(c.decide(5, &obs(0)), None, "schedule exhausted");
    }

    #[test]
    fn ladder_requires_the_starting_rung() {
        let plan = AdaptPlan::parse("ladder:uniform8,uniform4").unwrap();
        assert!(plan.controller("slacc").is_err());
        assert!(plan.controller("uniform8").is_ok());
    }

    #[test]
    fn ladder_steps_up_on_stable_entropy_with_cooldown() {
        let plan =
            AdaptPlan::parse("ladder:uniform8,uniform4,uniform2;cooldown=3").unwrap();
        let mut c = plan.controller("uniform8").unwrap();
        assert_eq!(c.decide(0, &obs(0)), None, "cooldown");
        assert_eq!(c.decide(1, &obs(0)), None, "cooldown");
        assert_eq!(c.decide(2, &obs(0)), Some("uniform4".into()));
        assert_eq!(c.decide(3, &obs(0)), None, "cooldown restarts");
        assert_eq!(c.decide(4, &obs(0)), None);
        assert_eq!(c.decide(5, &obs(0)), Some("uniform2".into()));
        // top of the ladder: stable entropy no longer steps
        assert_eq!(c.decide(8, &obs(0)), None);
        assert_eq!(c.decide(9, &obs(0)), None);
    }

    #[test]
    fn ladder_steps_down_on_drift_and_holds_in_the_dead_band() {
        let plan = AdaptPlan::parse(
            "ladder:uniform8,uniform4;cooldown=1;up-below=100;down-above=500",
        )
        .unwrap();
        let mut c = plan.controller("uniform4").unwrap();
        // dead band: between the thresholds nothing moves
        assert_eq!(c.decide(0, &obs(300)), None);
        assert_eq!(c.decide(1, &obs(499)), None);
        assert_eq!(c.decide(2, &obs(101)), None);
        // drift: step down toward fidelity
        assert_eq!(c.decide(3, &obs(500)), Some("uniform8".into()));
        // bottom of the ladder: drift cannot step further
        assert_eq!(c.decide(4, &obs(9999)), None);
        // stable again: climb back
        assert_eq!(c.decide(5, &obs(100)), Some("uniform4".into()));
    }

    #[test]
    fn retuned_specs_follow_the_uplink_but_pin_identity_downlink_and_sync() {
        let both = StreamSpecs::parse("slacc", "slacc", "identity").unwrap();
        let r = retuned_specs(&both, "uniform4").unwrap();
        assert_eq!(r.table(), "uplink=uniform4 downlink=uniform4 sync=identity");

        let nograd = StreamSpecs::parse("slacc", "identity", "uniform8").unwrap();
        let r = retuned_specs(&nograd, "uniform4").unwrap();
        assert_eq!(r.table(), "uplink=uniform4 downlink=identity sync=uniform8");

        assert!(retuned_specs(&both, "bogus").is_err());
    }

    #[test]
    fn spec_epochs_serve_rounds_by_activation() {
        let cfg = SessionStreamCfg {
            channels: 4,
            total_rounds: 20,
            seed: 7,
            slacc: SlAccConfig::default(),
            alpha: None,
        };
        let a = StreamSpecs::parse("uniform8", "uniform8", "identity").unwrap();
        let b = StreamSpecs::parse("uniform4", "uniform4", "identity").unwrap();
        let set = StreamSet::build(a.clone(), &cfg, 2).unwrap();
        let mut ep = SpecEpochs::new(set);
        assert_eq!(ep.len(), 1);
        assert_eq!(ep.devices(), 2);
        let next = ep.current().rebuilt(b.clone()).unwrap();
        ep.push(5, next);
        assert_eq!(ep.len(), 2);
        // rounds below the activation round stay on the old table
        assert_eq!(ep.for_round(4).specs(), &a);
        assert_eq!(ep.for_round(5).specs(), &b);
        assert_eq!(ep.for_round(19).specs(), &b);
        assert_eq!(ep.active_table(4), a.table());
        assert_eq!(ep.active_table(5), b.table());
        // sync instances are pinned to epoch 0
        assert_eq!(ep.sync_set().specs(), &a);
        assert_eq!(ep.current_specs(), &b);
        assert_eq!(ep.initial_specs(), &a);
    }

    #[test]
    fn rebuilt_sets_are_seed_identical_twins() {
        use crate::codecs::RoundCtx;
        let cfg = SessionStreamCfg {
            channels: 4,
            total_rounds: 20,
            seed: 7,
            slacc: SlAccConfig::default(),
            alpha: None,
        };
        let a = StreamSpecs::parse("uniform8", "uniform8", "identity").unwrap();
        let b = StreamSpecs::parse("randtopk", "randtopk", "identity").unwrap();
        let set = StreamSet::build(a, &cfg, 2).unwrap();
        let mut rebuilt = set.rebuilt(b.clone()).unwrap();
        // the device side builds fresh DeviceStreams from the same seeds:
        // a stochastic codec must produce identical envelopes on both ends
        let mut device_side =
            crate::codecs::stream::DeviceStreams::build(&b, &cfg, 1).unwrap();
        let cm = crate::codecs::test_support::random_cm(3, 4, 2, 2, 1);
        let w_srv = rebuilt.device(1).up.compress(&cm, RoundCtx::default());
        let w_dev = device_side.up.compress(&cm, RoundCtx::default());
        assert_eq!(w_srv, w_dev);
    }

    #[test]
    fn pending_update_ack_tracking() {
        let mut p = PendingUpdate { activate: 6, fp: 1, unacked: vec![true; 3] };
        assert!(!p.fully_acked());
        p.unacked[0] = false;
        p.unacked[2] = false;
        assert!(!p.fully_acked());
        p.unacked[1] = false;
        assert!(p.fully_acked());
    }
}
