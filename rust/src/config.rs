//! Experiment configuration: everything a training run needs, buildable
//! from CLI flags (see [`crate::cli`]) or programmatically from the benches.
//!
//! Codec configuration is the per-stream spec table
//! ([`crate::codecs::stream::StreamSpecs`]): `--codec` is shorthand for
//! both data directions, `--uplink-codec` / `--downlink-codec` /
//! `--sync-codec` override one stream each, and every codec instance is
//! built through the registry by [`ExperimentConfig::stream_set`] /
//! [`ExperimentConfig::device_streams`] — there is exactly one
//! construction path and one place stream seeds are derived.

use crate::codecs::selection::Selection;
use crate::codecs::stream::{
    self, DeviceStreams, SessionStreamCfg, StreamSet, StreamSpecs,
};
use crate::data::partition::Partition;
use crate::entropy::AlphaSchedule;
use crate::net::{DeviceLink, ServerModel};
use crate::sched::{Participation, Policy};
use crate::shard::Topology;

/// Which compressor runs on the smashed-data streams (the `--codec`
/// shorthand: applied to uplink and downlink unless overridden per
/// stream).
#[derive(Debug, Clone, PartialEq)]
pub enum CodecChoice {
    /// A registry spec string ("slacc", "uniform8", "ef:powerquant", ...).
    Named(String),
    /// Channel-selection ablation (Figs. 2/3/6): strategy + #channels.
    Select { strategy: Selection, n_select: usize },
}

impl CodecChoice {
    pub fn label(&self) -> String {
        match self {
            CodecChoice::Named(n) => n.clone(),
            CodecChoice::Select { strategy, n_select } => {
                format!("select-{}x{}", strategy.label(), n_select)
            }
        }
    }

    /// The registry spec string this choice resolves to.
    pub fn spec_str(&self) -> String {
        match self {
            CodecChoice::Named(n) => n.clone(),
            CodecChoice::Select { strategy, n_select } => {
                format!("select:{}:{}", strategy.label(), n_select)
            }
        }
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// model/dataset config name: "ham" | "mnist"
    pub dataset: String,
    /// root of the AOT artifacts (contains `<dataset>/manifest.json`)
    pub artifacts_root: String,
    pub devices: usize,
    pub rounds: usize,
    pub lr: f32,
    pub train_n: usize,
    pub test_n: usize,
    pub partition: Partition,
    /// shorthand codec for both data directions (see per-stream overrides)
    pub codec: CodecChoice,
    /// `--uplink-codec`: override the activations stream only
    pub uplink_codec: Option<String>,
    /// `--downlink-codec`: override the gradients stream only
    pub downlink_codec: Option<String>,
    /// evaluate test accuracy every this many rounds
    pub eval_every: usize,
    /// stop early once this test accuracy is reached
    pub target_accuracy: Option<f64>,
    /// FedAvg the client sub-models every this many rounds (1 = every round)
    pub client_agg_every: usize,
    /// ACII/CGC overrides (apply to the "slacc" codec)
    pub slacc: crate::codecs::slacc::SlAccConfig,
    /// override the α schedule for slacc / selection codecs (Fig. 4)
    pub alpha: Option<AlphaSchedule>,
    pub link: DeviceLink,
    pub server: ServerModel,
    /// per-device speed factors (empty = homogeneous 1.0)
    pub device_speeds: Vec<f64>,
    pub seed: u64,
    /// compute entropy with the AOT Pallas kernel (true) or the host mirror
    /// (false). The kernel path is the paper-faithful hot path; the host
    /// mirror exists for engine-less unit tests and perf comparison.
    pub entropy_via_kernel: bool,
    /// also compress the downlink gradients (paper does both directions)
    pub compress_gradients: bool,
    /// round-scheduling policy: InOrder (deterministic default) or
    /// ArrivalOrder with optional straggler timeout + quorum
    pub schedule: Policy,
    /// `--sync-codec`: codec spec for the ModelSync (FedAvg) streams;
    /// None = "identity" (lossless, envelope-wrapped raw f32)
    pub sync_codec: Option<String>,
    /// `--batch-window`: max same-shaped Activations the server coalesces
    /// into one `server_step_batch` dispatch (1 = per-device dispatch, the
    /// historical behavior). Only arrival-order scheduling batches;
    /// InOrder forces 1. Fingerprinted: a batched engine session's fused
    /// update changes numerics, so fleets must agree on the window.
    pub batch_window: usize,
    /// `--shards`: how many shard servers the device fleet is partitioned
    /// across (1 = single server, the historical topology). Fingerprinted:
    /// sharding changes the server-model update order, so every node of a
    /// cluster must agree.
    pub shards: usize,
    /// `--shard-sync-every`: cross-shard FedAvg cadence in rounds (only
    /// meaningful with `--shards > 1`). Fingerprinted for the same reason.
    pub shard_sync_every: usize,
    /// `--adapt`: runtime renegotiation directive (`at:R=<spec>,...` or
    /// `ladder:<spec>,...`; see [`crate::adapt::AdaptPlan`]). None = the
    /// negotiated spec table is fixed for the session (the historical
    /// behavior). Fingerprinted: both ends must agree on whether the
    /// session may retune mid-run.
    pub adapt: Option<String>,
    /// `--elastic`: keep the listener armed after session start and let
    /// devices leave / re-join mid-run (proto v6 Join/Leave/Catchup; see
    /// [`crate::member`]). Requires arrival-order scheduling — the
    /// in-order schedule's byte-determinism contract cannot absorb a
    /// shrinking participant set. Fingerprinted: a device must know the
    /// session admits re-joins before it attempts one.
    pub elastic: bool,
    /// `--select`: round-participation policy (see
    /// [`crate::sched::Participation`]). Fingerprinted: who participates
    /// changes every downstream numeric.
    pub participation: Participation,
}

impl ExperimentConfig {
    /// Paper-default configuration for a dataset ("ham" | "mnist").
    pub fn default_for(dataset: &str) -> ExperimentConfig {
        ExperimentConfig {
            dataset: dataset.to_string(),
            artifacts_root: "artifacts".into(),
            devices: 5,            // paper Sec. III-A4
            rounds: 300,
            lr: 1e-3,
            train_n: 2000,
            test_n: 512,
            partition: Partition::Iid,
            codec: CodecChoice::Named("slacc".into()),
            uplink_codec: None,
            downlink_codec: None,
            eval_every: 10,
            target_accuracy: None,
            client_agg_every: 1,
            slacc: crate::codecs::slacc::SlAccConfig::default(),
            alpha: None,
            link: DeviceLink::default(),
            server: ServerModel::default(),
            device_speeds: Vec::new(),
            seed: 0,
            entropy_via_kernel: true,
            compress_gradients: true,
            schedule: Policy::InOrder,
            sync_codec: None,
            batch_window: 1,
            shards: 1,
            shard_sync_every: 1,
            adapt: None,
            elastic: false,
            participation: Participation::All,
        }
    }

    /// Artifacts directory for this run.
    pub fn artifacts_dir(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.artifacts_root).join(&self.dataset)
    }

    /// Resolve the flags into the negotiated per-stream spec table: the
    /// `--codec` shorthand covers both data directions unless a per-stream
    /// override is set; the downlink falls back to lossless identity when
    /// gradient compression is off; sync defaults to identity.
    pub fn stream_specs(&self) -> Result<StreamSpecs, String> {
        let base = self.codec.spec_str();
        let uplink = self.uplink_codec.clone().unwrap_or_else(|| base.clone());
        let downlink = if self.compress_gradients {
            self.downlink_codec.clone().unwrap_or(base)
        } else {
            "identity".to_string()
        };
        let sync = self.sync_codec.clone().unwrap_or_else(|| "identity".to_string());
        StreamSpecs::parse(&uplink, &downlink, &sync).map_err(String::from)
    }

    /// The shared session parameters every stream build uses.
    pub(crate) fn session_stream_cfg(&self, channels: usize) -> SessionStreamCfg {
        SessionStreamCfg {
            channels,
            total_rounds: self.rounds,
            seed: self.seed,
            slacc: self.slacc,
            alpha: self.alpha,
        }
    }

    /// Build the full fleet's per-device, per-direction codec instances
    /// (the server side of a session).
    pub fn stream_set(&self, channels: usize) -> Result<StreamSet, String> {
        let specs = self.stream_specs()?;
        StreamSet::build(specs, &self.session_stream_cfg(channels), self.devices)
            .map_err(String::from)
    }

    /// Build the stream codecs for the device slice shard `shard_id`
    /// serves (locally indexed, globally seeded — see
    /// [`StreamSet::build_range`]).
    pub fn stream_set_for_shard(
        &self,
        channels: usize,
        shard_id: usize,
    ) -> Result<StreamSet, String> {
        let shape = self.topology().shape_for(self.devices, shard_id);
        let specs = self.stream_specs()?;
        StreamSet::build_range(
            specs,
            &self.session_stream_cfg(channels),
            shape.base,
            shape.local,
        )
        .map_err(String::from)
    }

    /// Build the codec pair for shard `shard_id`'s coordinator link:
    /// `(push, broadcast)` twins of the `--sync-codec` stream. Both ends
    /// call this with the same arguments and hold identical instances.
    pub fn shard_link_streams(
        &self,
        shard_id: usize,
    ) -> Result<(Box<dyn crate::codecs::Codec>, Box<dyn crate::codecs::Codec>), String>
    {
        let specs = self.stream_specs()?;
        // shard links carry flattened parameters: one logical channel
        stream::shard_sync_streams(&specs, &self.session_stream_cfg(1), shard_id)
            .map_err(String::from)
    }

    /// Build one device's four stream codecs (the device side of a
    /// session; the server's [`StreamSet`] holds the identical twins).
    pub fn device_streams(&self, channels: usize, device: usize) -> Result<DeviceStreams, String> {
        let specs = self.stream_specs()?;
        DeviceStreams::build(&specs, &self.session_stream_cfg(channels), device)
            .map_err(String::from)
    }

    /// The cluster topology these flags describe.
    pub fn topology(&self) -> Topology {
        Topology { shards: self.shards, sync_every: self.shard_sync_every }
    }

    /// Project this experiment onto the shape a transport server session
    /// enforces. `eval_batch` comes from the model geometry (the artifact
    /// manifest's batch, or the mock batch). A single server is shard 0
    /// of a 1-shard topology.
    pub fn serve_config(
        &self,
        eval_batch: usize,
    ) -> Result<crate::transport::server::ServeConfig, String> {
        self.serve_config_for_shard(eval_batch, 0)
    }

    /// [`ExperimentConfig::serve_config`] for shard `shard_id` of the
    /// configured topology: the runtime serves the contiguous global
    /// device-id slice [`Topology::shape_for`] assigns to it.
    pub fn serve_config_for_shard(
        &self,
        eval_batch: usize,
        shard_id: usize,
    ) -> Result<crate::transport::server::ServeConfig, String> {
        let topo = self.topology();
        topo.validate(self.devices, self.client_agg_every)?;
        if shard_id >= topo.shards {
            return Err(format!(
                "shard id {shard_id} out of range ({} shards)",
                topo.shards
            ));
        }
        let shape = topo.shape_for(self.devices, shard_id);
        Ok(crate::transport::server::ServeConfig {
            devices: shape.local,
            global_devices: shape.global,
            device_base: shape.base,
            rounds: self.rounds,
            lr: self.lr,
            eval_every: self.eval_every,
            client_agg_every: self.client_agg_every,
            target_accuracy: self.target_accuracy,
            compress_gradients: self.compress_gradients,
            label: self.codec.label(),
            eval_batch,
            config_fp: self.fingerprint(),
            schedule: self.schedule,
            batch_window: self.batch_window,
            specs: self.stream_specs()?,
            adapt: self.adapt.clone(),
            elastic: self.elastic,
            participation: self.participation,
        })
    }

    /// Whether the AOT artifacts for this config exist on disk (if not,
    /// only `--mock` transport sessions can run).
    pub fn have_artifacts(&self) -> bool {
        self.artifacts_dir().join("manifest.json").exists()
    }

    /// Stable 64-bit digest of every field that changes a session's
    /// numerics or byte accounting. The transport Hello carries it so a
    /// `slacc device` launched with different flags than the server (lr,
    /// seed, dataset sizes, partition, stream specs, ...) is rejected
    /// at handshake instead of silently corrupting the run. FNV-1a over a
    /// canonical string, so it is identical across processes and builds.
    /// The per-stream spec table additionally travels verbatim in the
    /// Hello, so a stream mismatch is reported by name instead of as an
    /// opaque digest difference.
    pub fn fingerprint(&self) -> u64 {
        let streams = self
            .stream_specs()
            .map(|s| s.table())
            .unwrap_or_else(|e| format!("invalid({e})"));
        let repr = format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{:?}|{}|{}|{}|{}|{}|{}|{}",
            self.dataset,
            self.seed,
            self.lr.to_bits(),
            self.train_n,
            self.test_n,
            self.devices,
            self.rounds,
            self.eval_every,
            self.client_agg_every,
            self.compress_gradients,
            self.entropy_via_kernel,
            self.partition.label(),
            streams,
            self.slacc.groups,
            self.slacc.history_window,
            self.slacc.b_min,
            self.slacc.b_max,
            self.alpha,
            self.schedule.label(),
            self.batch_window,
            self.shards,
            self.shard_sync_every,
            self.adapt.as_deref().unwrap_or("-"),
            self.elastic,
            self.participation.label(),
        );
        crate::codecs::stream::fnv1a(&repr)
    }

    /// The full fleet's network simulator. With `shards == 1` (the
    /// default) this is the whole-fleet slice of [`Self::network_for_shard`];
    /// sharded in-process trainers never call it.
    pub fn network(&self) -> crate::net::NetworkSim {
        let full = crate::shard::Topology::single().shape_for(self.devices, 0);
        self.network_for_slice(full)
    }

    /// The network simulator for the device slice shard `shard_id` serves
    /// (heterogeneous speeds are sliced by global device id, so a device
    /// keeps its link whichever shard it lands on).
    pub fn network_for_shard(&self, shard_id: usize) -> crate::net::NetworkSim {
        self.network_for_slice(self.topology().shape_for(self.devices, shard_id))
    }

    fn network_for_slice(&self, shape: crate::shard::FleetShape) -> crate::net::NetworkSim {
        if self.device_speeds.is_empty() {
            crate::net::NetworkSim::homogeneous(shape.local, self.link, self.server)
        } else {
            assert_eq!(self.device_speeds.len(), self.devices);
            crate::net::NetworkSim::heterogeneous(
                self.link,
                &self.device_speeds[shape.base..shape.base + shape.local],
                self.server,
            )
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.devices == 0 {
            return Err("devices must be >= 1".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if self.eval_every == 0 {
            return Err("eval_every must be >= 1".into());
        }
        if self.client_agg_every == 0 {
            return Err("client_agg_every must be >= 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be > 0".into());
        }
        if !self.device_speeds.is_empty() && self.device_speeds.len() != self.devices {
            return Err(format!(
                "device_speeds has {} entries for {} devices",
                self.device_speeds.len(),
                self.devices
            ));
        }
        if !self.compress_gradients && self.downlink_codec.is_some() {
            return Err(
                "--downlink-codec contradicts --no-grad-compress (the uncompressed \
                 downlink is always the identity stream)"
                    .into(),
            );
        }
        if self.batch_window == 0 {
            return Err("batch window must be >= 1".into());
        }
        self.topology().validate(self.devices, self.client_agg_every)?;
        // parses (and therefore registry-validates) all three stream specs
        let specs = self.stream_specs()?;
        if let Some(directive) = self.adapt.as_deref() {
            if self.shards > 1 {
                return Err(
                    "--adapt is single-server only (cross-shard epoch agreement \
                     is not coordinated yet)"
                        .into(),
                );
            }
            // full parse + ladder/initial-spec consistency, same path the
            // server runtime takes at session start
            crate::adapt::AdaptState::from_directive(directive, &specs)?;
        }
        if self.elastic {
            if !matches!(self.schedule, Policy::ArrivalOrder { .. }) {
                return Err(
                    "--elastic requires --schedule arrival (the in-order \
                     schedule's byte-determinism contract cannot absorb a \
                     shrinking participant set)"
                        .into(),
                );
            }
            if self.adapt.is_some() {
                return Err(
                    "--elastic and --adapt are mutually exclusive for now (a \
                     re-joining device cannot replay a mid-session spec \
                     renegotiation)"
                        .into(),
                );
            }
        }
        if self.participation == Participation::BiasStragglers
            && !matches!(self.schedule, Policy::ArrivalOrder { .. })
        {
            return Err(
                "--select bias-stragglers requires --schedule arrival (the \
                 in-order schedule has no straggler history to bias on)"
                    .into(),
            );
        }
        if let Policy::ArrivalOrder { straggler_timeout_s, min_quorum } = self.schedule {
            if let Some(t) = straggler_timeout_s {
                if !(t > 0.0) {
                    return Err("straggler timeout must be > 0".into());
                }
            }
            if let Some(q) = min_quorum {
                if q == 0 || q > self.devices {
                    return Err(format!(
                        "min quorum {q} out of range (devices={})",
                        self.devices
                    ));
                }
                if straggler_timeout_s.is_none() {
                    return Err(
                        "--min-quorum needs --straggler-timeout (a quorum only \
                         matters when a timed-out round can close early)"
                            .into(),
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default_for("ham").validate().unwrap();
        ExperimentConfig::default_for("mnist").validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.codec = CodecChoice::Named("nope".into());
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.uplink_codec = Some("nope".into());
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.device_speeds = vec![1.0, 2.0];
        assert!(c.validate().is_err());

        // a downlink override is meaningless with gradient compression off
        let mut c = ExperimentConfig::default_for("ham");
        c.compress_gradients = false;
        c.downlink_codec = Some("uniform8".into());
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.batch_window = 0;
        assert!(c.validate().is_err());

        // 5 devices do not split across 2 shards
        let mut c = ExperimentConfig::default_for("ham");
        c.shards = 2;
        assert!(c.validate().is_err());

        // sync cadence must land on aggregation rounds
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 4;
        c.shards = 2;
        c.client_agg_every = 2;
        c.shard_sync_every = 3;
        assert!(c.validate().is_err());
        c.shard_sync_every = 4;
        c.validate().unwrap();
    }

    #[test]
    fn topology_is_fingerprinted_and_shapes_serve_config() {
        let mut a = ExperimentConfig::default_for("ham");
        a.devices = 4;
        let mut b = a.clone();
        b.shards = 2;
        b.validate().unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = b.clone();
        c.shard_sync_every = 4;
        c.validate().unwrap();
        assert_ne!(b.fingerprint(), c.fingerprint());

        // shard 1 of 2 serves global devices 2..4 as local slots 0..2
        let s = b.serve_config_for_shard(32, 1).unwrap();
        assert_eq!(s.devices, 2);
        assert_eq!(s.global_devices, 4);
        assert_eq!(s.device_base, 2);
        assert_eq!(s.gid(0), 2);
        assert_eq!(s.gid(1), 3);
        assert!(b.serve_config_for_shard(32, 2).is_err());

        // the unsharded projection keeps the flat shape
        let s = a.serve_config(32).unwrap();
        assert_eq!(s.devices, 4);
        assert_eq!(s.global_devices, 4);
        assert_eq!(s.device_base, 0);

        // shard stream sets are locally indexed
        let set = b.stream_set_for_shard(8, 1).unwrap();
        assert_eq!(set.devices(), 2);
        // shard link codecs build for the sync spec
        let (push, bcast) = b.shard_link_streams(0).unwrap();
        assert_eq!(push.name(), "identity");
        assert_eq!(bcast.name(), "identity");
        // per-shard network slices the fleet
        assert_eq!(b.network_for_shard(1).devices(), 2);
    }

    #[test]
    fn batch_window_is_fingerprinted_and_projected() {
        let a = ExperimentConfig::default_for("ham");
        let mut b = ExperimentConfig::default_for("ham");
        b.batch_window = 8;
        b.schedule = Policy::arrival();
        b.validate().unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = ExperimentConfig::default_for("ham");
        c.schedule = Policy::arrival();
        assert_ne!(
            b.fingerprint(),
            c.fingerprint(),
            "window must be fingerprinted independently of the schedule"
        );
        assert_eq!(b.serve_config(32).unwrap().batch_window, 8);
        assert_eq!(a.serve_config(32).unwrap().batch_window, 1);
    }

    #[test]
    fn stream_specs_resolve_shorthand_and_overrides() {
        let mut c = ExperimentConfig::default_for("ham");
        let s = c.stream_specs().unwrap();
        assert_eq!(s.uplink.as_str(), "slacc");
        assert_eq!(s.downlink.as_str(), "slacc");
        assert_eq!(s.sync.as_str(), "identity");

        c.downlink_codec = Some("uniform8".into());
        c.sync_codec = Some("uniform8".into());
        let s = c.stream_specs().unwrap();
        assert_eq!(s.uplink.as_str(), "slacc");
        assert_eq!(s.downlink.as_str(), "uniform8");
        assert_eq!(s.sync.as_str(), "uniform8");

        c.uplink_codec = Some("ef:powerquant".into());
        let s = c.stream_specs().unwrap();
        assert_eq!(s.uplink.as_str(), "ef:powerquant");
    }

    #[test]
    fn selection_choice_resolves_through_the_registry() {
        let mut c = ExperimentConfig::default_for("ham");
        c.codec = CodecChoice::Select {
            strategy: Selection::EntropyBlended,
            n_select: 1,
        };
        let s = c.stream_specs().unwrap();
        assert_eq!(s.uplink.as_str(), "select:acii:1");
        let ds = c.device_streams(32, 0).unwrap();
        assert_eq!(ds.up.name(), "select-acii");
    }

    #[test]
    fn alpha_override_applies_to_slacc() {
        let mut c = ExperimentConfig::default_for("ham");
        c.alpha = Some(AlphaSchedule::Fixed(0.25));
        let ds = c.device_streams(8, 0).unwrap();
        assert_eq!(ds.up.name(), "slacc"); // built without panic
    }

    #[test]
    fn downlink_is_identity_when_uncompressed() {
        let mut c = ExperimentConfig::default_for("ham");
        assert_eq!(c.device_streams(8, 0).unwrap().down.name(), "slacc");
        c.compress_gradients = false;
        let ds = c.device_streams(8, 0).unwrap();
        assert_eq!(ds.down.name(), "identity");
        // uplink is unaffected by the gradient-compression switch
        assert_eq!(ds.up.name(), "slacc");
    }

    #[test]
    fn serve_config_projection() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 4;
        c.rounds = 3;
        let s = c.serve_config(32).unwrap();
        assert_eq!(s.devices, 4);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.eval_batch, 32);
        assert_eq!(s.label, "slacc");
        assert_eq!(s.config_fp, c.fingerprint());
        assert_eq!(s.specs, c.stream_specs().unwrap());
    }

    #[test]
    fn fingerprint_tracks_numerics_affecting_flags() {
        let a = ExperimentConfig::default_for("ham");
        assert_eq!(a.fingerprint(), ExperimentConfig::default_for("ham").fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.lr = 0.1;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.seed = 1;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.partition = Partition::Dirichlet { beta: 0.5 };
        assert_ne!(a.fingerprint(), b.fingerprint());

        // every per-stream override is numerics-affecting
        let mut b = ExperimentConfig::default_for("ham");
        b.uplink_codec = Some("uniform8".into());
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut b = ExperimentConfig::default_for("ham");
        b.downlink_codec = Some("uniform8".into());
        assert_ne!(a.fingerprint(), b.fingerprint());

        // artifacts location is deployment detail, not numerics
        let mut b = ExperimentConfig::default_for("ham");
        b.artifacts_root = "elsewhere".into();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn schedule_and_sync_codec_are_fingerprinted() {
        let a = ExperimentConfig::default_for("ham");
        let mut b = ExperimentConfig::default_for("ham");
        b.schedule = Policy::arrival();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut b2 = ExperimentConfig::default_for("ham");
        b2.schedule = Policy::arrival_with_timeout(0.5, 3);
        assert_ne!(b.fingerprint(), b2.fingerprint());
        let mut c = ExperimentConfig::default_for("ham");
        c.sync_codec = Some("uniform8".into());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(c.device_streams(8, 0).unwrap().sync_up.name(), "uniform8");
        assert_eq!(a.device_streams(8, 0).unwrap().sync_up.name(), "identity");
        assert_eq!(a.device_streams(8, 1).unwrap().sync_down.name(), "identity");
    }

    #[test]
    fn schedule_validation() {
        let mut c = ExperimentConfig::default_for("ham");
        c.schedule = Policy::arrival();
        c.validate().unwrap();
        c.schedule =
            Policy::ArrivalOrder { straggler_timeout_s: Some(-1.0), min_quorum: None };
        assert!(c.validate().is_err());
        c.schedule =
            Policy::ArrivalOrder { straggler_timeout_s: Some(0.5), min_quorum: Some(0) };
        assert!(c.validate().is_err());
        // quorum without a timeout is meaningless
        c.schedule = Policy::ArrivalOrder { straggler_timeout_s: None, min_quorum: Some(2) };
        assert!(c.validate().is_err());
        // quorum larger than the fleet
        c.schedule = Policy::arrival_with_timeout(0.5, 99);
        assert!(c.validate().is_err());
        c.schedule = Policy::arrival_with_timeout(0.5, 3);
        c.validate().unwrap();
        c.sync_codec = Some("bogus".into());
        assert!(c.validate().is_err());
    }

    #[test]
    fn elastic_and_participation_are_validated_and_fingerprinted() {
        let a = ExperimentConfig::default_for("ham");

        // elastic needs the arrival schedule and no adapt directive
        let mut b = ExperimentConfig::default_for("ham");
        b.elastic = true;
        assert!(b.validate().is_err(), "elastic under InOrder must be rejected");
        b.schedule = Policy::arrival();
        b.validate().unwrap();
        assert_ne!(a.fingerprint(), b.fingerprint());
        b.adapt = Some("at:2=uniform8".into());
        assert!(b.validate().is_err(), "elastic + adapt must be rejected");

        // bias-stragglers needs arrival scheduling too
        let mut c = ExperimentConfig::default_for("ham");
        c.participation = Participation::BiasStragglers;
        assert!(c.validate().is_err());
        c.schedule = Policy::arrival();
        c.validate().unwrap();
        let mut c_all = ExperimentConfig::default_for("ham");
        c_all.schedule = Policy::arrival();
        assert_ne!(c.fingerprint(), c_all.fingerprint());

        // both project onto the serve config
        let mut d = ExperimentConfig::default_for("ham");
        d.schedule = Policy::arrival();
        d.elastic = true;
        d.participation = Participation::BiasStragglers;
        let s = d.serve_config(32).unwrap();
        assert!(s.elastic);
        assert_eq!(s.participation, Participation::BiasStragglers);
        let s = a.serve_config(32).unwrap();
        assert!(!s.elastic);
        assert_eq!(s.participation, Participation::All);
    }

    #[test]
    fn network_heterogeneous() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 3;
        c.device_speeds = vec![1.0, 0.5, 2.0];
        let net = c.network();
        assert_eq!(net.devices(), 3);
        assert!(net.links[1].t_client_fwd > net.links[0].t_client_fwd);
    }
}
