//! Experiment configuration: everything a training run needs, buildable
//! from CLI flags (see [`crate::cli`]) or programmatically from the benches.

use crate::codecs;
use crate::codecs::selection::Selection;
use crate::data::partition::Partition;
use crate::entropy::AlphaSchedule;
use crate::net::{DeviceLink, ServerModel};
use crate::sched::Policy;

/// Which compressor runs on the smashed-data streams.
#[derive(Debug, Clone, PartialEq)]
pub enum CodecChoice {
    /// A codec from [`codecs::by_name`] ("slacc", "powerquant", ...).
    Named(String),
    /// Channel-selection ablation (Figs. 2/3/6): strategy + #channels.
    Select { strategy: Selection, n_select: usize },
}

impl CodecChoice {
    pub fn label(&self) -> String {
        match self {
            CodecChoice::Named(n) => n.clone(),
            CodecChoice::Select { strategy, n_select } => {
                format!("select-{}x{}", strategy.label(), n_select)
            }
        }
    }
}

/// Full specification of one training run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// model/dataset config name: "ham" | "mnist"
    pub dataset: String,
    /// root of the AOT artifacts (contains `<dataset>/manifest.json`)
    pub artifacts_root: String,
    pub devices: usize,
    pub rounds: usize,
    pub lr: f32,
    pub train_n: usize,
    pub test_n: usize,
    pub partition: Partition,
    pub codec: CodecChoice,
    /// evaluate test accuracy every this many rounds
    pub eval_every: usize,
    /// stop early once this test accuracy is reached
    pub target_accuracy: Option<f64>,
    /// FedAvg the client sub-models every this many rounds (1 = every round)
    pub client_agg_every: usize,
    /// ACII/CGC overrides (apply to the "slacc" codec)
    pub slacc: crate::codecs::slacc::SlAccConfig,
    /// override the α schedule for slacc / selection codecs (Fig. 4)
    pub alpha: Option<AlphaSchedule>,
    pub link: DeviceLink,
    pub server: ServerModel,
    /// per-device speed factors (empty = homogeneous 1.0)
    pub device_speeds: Vec<f64>,
    pub seed: u64,
    /// compute entropy with the AOT Pallas kernel (true) or the host mirror
    /// (false). The kernel path is the paper-faithful hot path; the host
    /// mirror exists for engine-less unit tests and perf comparison.
    pub entropy_via_kernel: bool,
    /// also compress the downlink gradients (paper does both directions)
    pub compress_gradients: bool,
    /// round-scheduling policy: InOrder (deterministic default) or
    /// ArrivalOrder with optional straggler timeout + quorum
    pub schedule: Policy,
    /// codec name for the ModelSync (FedAvg) streams; None = "identity"
    /// (lossless, envelope-wrapped raw f32)
    pub sync_codec: Option<String>,
}

impl ExperimentConfig {
    /// Paper-default configuration for a dataset ("ham" | "mnist").
    pub fn default_for(dataset: &str) -> ExperimentConfig {
        ExperimentConfig {
            dataset: dataset.to_string(),
            artifacts_root: "artifacts".into(),
            devices: 5,            // paper Sec. III-A4
            rounds: 300,
            lr: 1e-3,
            train_n: 2000,
            test_n: 512,
            partition: Partition::Iid,
            codec: CodecChoice::Named("slacc".into()),
            eval_every: 10,
            target_accuracy: None,
            client_agg_every: 1,
            slacc: crate::codecs::slacc::SlAccConfig::default(),
            alpha: None,
            link: DeviceLink::default(),
            server: ServerModel::default(),
            device_speeds: Vec::new(),
            seed: 0,
            entropy_via_kernel: true,
            compress_gradients: true,
            schedule: Policy::InOrder,
            sync_codec: None,
        }
    }

    /// Artifacts directory for this run.
    pub fn artifacts_dir(&self) -> std::path::PathBuf {
        std::path::Path::new(&self.artifacts_root).join(&self.dataset)
    }

    /// Instantiate the uplink/downlink codec for one device stream.
    /// `stream` namespaces the RNG so every device/direction differs.
    pub fn build_codec(&self, channels: usize, stream: u64)
                       -> Result<Box<dyn codecs::Codec>, String> {
        let seed = self.seed ^ (0x0dec << 16) ^ stream;
        match &self.codec {
            CodecChoice::Named(name) => {
                if name == "slacc" || name == "slacc-paper-eq6" {
                    let mut cfg = self.slacc;
                    if name == "slacc-paper-eq6" {
                        cfg.bit_alloc = crate::codecs::slacc::BitAlloc::FloorEntropy;
                    }
                    if let Some(a) = self.alpha {
                        cfg.alpha = a;
                    }
                    Ok(Box::new(crate::codecs::slacc::SlAccCodec::new(
                        cfg, channels, self.rounds, seed,
                    )))
                } else {
                    codecs::by_name(name, channels, self.rounds, seed)
                }
            }
            CodecChoice::Select { strategy, n_select } => {
                Ok(Box::new(codecs::selection::SelectionCodec::new(
                    *strategy,
                    *n_select,
                    channels,
                    self.slacc.history_window,
                    self.rounds,
                    seed,
                )))
            }
        }
    }

    /// The uplink (activations) codec for device `device`. The compressing
    /// instance lives on the device; the server builds an identical twin to
    /// decompress (the wire envelopes are self-describing).
    pub fn uplink_codec(&self, channels: usize, device: usize)
                        -> Result<Box<dyn codecs::Codec>, String> {
        self.build_codec(channels, (device as u64) * 2)
    }

    /// The downlink (gradients) codec for device `device`. When gradient
    /// compression is off this is [`codecs::identity::IdentityCodec`], so
    /// the uncompressed path still pays the payload envelope header and the
    /// "communication overhead" axis stays comparable across configs.
    pub fn downlink_codec(&self, channels: usize, device: usize)
                          -> Result<Box<dyn codecs::Codec>, String> {
        if self.compress_gradients {
            self.build_codec(channels, (device as u64) * 2 + 1)
        } else {
            Ok(Box::new(codecs::identity::IdentityCodec::new()))
        }
    }

    /// The ModelSync codec name ("identity" unless `--sync-codec` set).
    pub fn sync_codec_name(&self) -> &str {
        self.sync_codec.as_deref().unwrap_or("identity")
    }

    fn sync_stream_codec(&self, stream: u64) -> Result<Box<dyn codecs::Codec>, String> {
        // sync streams are independent of the smashed-data streams: their
        // own seed offset, one "channel" (params are flattened), and the
        // configured sync codec family
        codecs::by_name(
            self.sync_codec_name(),
            1,
            self.rounds,
            self.seed ^ (0x5106 << 20) ^ stream,
        )
    }

    /// The ModelSync compressor for device `device`'s pushes (the server
    /// builds an identical twin to decompress).
    pub fn sync_uplink_codec(&self, device: usize)
                             -> Result<Box<dyn codecs::Codec>, String> {
        self.sync_stream_codec((device as u64) * 2)
    }

    /// The ModelSync compressor for the server's FedAvg broadcast to
    /// device `device` (the device builds the decompress twin).
    pub fn sync_downlink_codec(&self, device: usize)
                               -> Result<Box<dyn codecs::Codec>, String> {
        self.sync_stream_codec((device as u64) * 2 + 1)
    }

    /// Project this experiment onto the shape a transport server session
    /// enforces. `eval_batch` comes from the model geometry (the artifact
    /// manifest's batch, or the mock batch).
    pub fn serve_config(&self, eval_batch: usize) -> crate::transport::server::ServeConfig {
        crate::transport::server::ServeConfig {
            devices: self.devices,
            rounds: self.rounds,
            lr: self.lr,
            eval_every: self.eval_every,
            client_agg_every: self.client_agg_every,
            target_accuracy: self.target_accuracy,
            compress_gradients: self.compress_gradients,
            label: self.codec.label(),
            eval_batch,
            config_fp: self.fingerprint(),
            schedule: self.schedule,
        }
    }

    /// Whether the AOT artifacts for this config exist on disk (if not,
    /// only `--mock` transport sessions can run).
    pub fn have_artifacts(&self) -> bool {
        self.artifacts_dir().join("manifest.json").exists()
    }

    /// Stable 64-bit digest of every field that changes a session's
    /// numerics or byte accounting. The transport Hello carries it so a
    /// `slacc device` launched with different flags than the server (lr,
    /// seed, dataset sizes, partition, codec parameters, ...) is rejected
    /// at handshake instead of silently corrupting the run. FNV-1a over a
    /// canonical string, so it is identical across processes and builds.
    pub fn fingerprint(&self) -> u64 {
        let repr = format!(
            "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{:?}|{}|{}",
            self.dataset,
            self.seed,
            self.lr.to_bits(),
            self.train_n,
            self.test_n,
            self.devices,
            self.rounds,
            self.eval_every,
            self.client_agg_every,
            self.compress_gradients,
            self.entropy_via_kernel,
            self.partition.label(),
            self.codec.label(),
            self.slacc.groups,
            self.slacc.history_window,
            self.slacc.b_min,
            self.slacc.b_max,
            self.alpha,
            self.schedule.label(),
            self.sync_codec_name(),
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in repr.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// The fleet's network simulator.
    pub fn network(&self) -> crate::net::NetworkSim {
        if self.device_speeds.is_empty() {
            crate::net::NetworkSim::homogeneous(self.devices, self.link, self.server)
        } else {
            assert_eq!(self.device_speeds.len(), self.devices);
            crate::net::NetworkSim::heterogeneous(self.link, &self.device_speeds, self.server)
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.devices == 0 {
            return Err("devices must be >= 1".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if self.eval_every == 0 {
            return Err("eval_every must be >= 1".into());
        }
        if self.client_agg_every == 0 {
            return Err("client_agg_every must be >= 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be > 0".into());
        }
        if !self.device_speeds.is_empty() && self.device_speeds.len() != self.devices {
            return Err(format!(
                "device_speeds has {} entries for {} devices",
                self.device_speeds.len(),
                self.devices
            ));
        }
        if let CodecChoice::Named(n) = &self.codec {
            let base = n.strip_prefix("ef:").unwrap_or(n);
            if !codecs::ALL_CODECS.contains(&base) {
                return Err(format!("unknown codec '{n}'"));
            }
        }
        {
            let n = self.sync_codec_name();
            let base = n.strip_prefix("ef:").unwrap_or(n);
            if !codecs::ALL_CODECS.contains(&base) {
                return Err(format!("unknown sync codec '{n}'"));
            }
        }
        if let Policy::ArrivalOrder { straggler_timeout_s, min_quorum } = self.schedule {
            if let Some(t) = straggler_timeout_s {
                if !(t > 0.0) {
                    return Err("straggler timeout must be > 0".into());
                }
            }
            if let Some(q) = min_quorum {
                if q == 0 || q > self.devices {
                    return Err(format!(
                        "min quorum {q} out of range (devices={})",
                        self.devices
                    ));
                }
                if straggler_timeout_s.is_none() {
                    return Err(
                        "--min-quorum needs --straggler-timeout (a quorum only \
                         matters when a timed-out round can close early)"
                            .into(),
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default_for("ham").validate().unwrap();
        ExperimentConfig::default_for("mnist").validate().unwrap();
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 0;
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.codec = CodecChoice::Named("nope".into());
        assert!(c.validate().is_err());

        let mut c = ExperimentConfig::default_for("ham");
        c.device_speeds = vec![1.0, 2.0];
        assert!(c.validate().is_err());
    }

    #[test]
    fn build_codec_named_and_selection() {
        let mut c = ExperimentConfig::default_for("ham");
        assert_eq!(c.build_codec(32, 0).unwrap().name(), "slacc");
        c.codec = CodecChoice::Named("powerquant".into());
        assert_eq!(c.build_codec(32, 0).unwrap().name(), "powerquant");
        c.codec = CodecChoice::Select {
            strategy: Selection::EntropyBlended,
            n_select: 1,
        };
        assert_eq!(c.build_codec(32, 0).unwrap().name(), "select-acii");
    }

    #[test]
    fn alpha_override_applies_to_slacc() {
        let mut c = ExperimentConfig::default_for("ham");
        c.alpha = Some(AlphaSchedule::Fixed(0.25));
        let codec = c.build_codec(8, 0).unwrap();
        assert_eq!(codec.name(), "slacc"); // built without panic
    }

    #[test]
    fn downlink_codec_is_identity_when_uncompressed() {
        let mut c = ExperimentConfig::default_for("ham");
        assert_eq!(c.downlink_codec(8, 0).unwrap().name(), "slacc");
        c.compress_gradients = false;
        assert_eq!(c.downlink_codec(8, 0).unwrap().name(), "identity");
        // uplink is unaffected by the gradient-compression switch
        assert_eq!(c.uplink_codec(8, 0).unwrap().name(), "slacc");
    }

    #[test]
    fn serve_config_projection() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 4;
        c.rounds = 3;
        let s = c.serve_config(32);
        assert_eq!(s.devices, 4);
        assert_eq!(s.rounds, 3);
        assert_eq!(s.eval_batch, 32);
        assert_eq!(s.label, "slacc");
        assert_eq!(s.config_fp, c.fingerprint());
    }

    #[test]
    fn fingerprint_tracks_numerics_affecting_flags() {
        let a = ExperimentConfig::default_for("ham");
        assert_eq!(a.fingerprint(), ExperimentConfig::default_for("ham").fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.lr = 0.1;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.seed = 1;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let mut b = ExperimentConfig::default_for("ham");
        b.partition = Partition::Dirichlet { beta: 0.5 };
        assert_ne!(a.fingerprint(), b.fingerprint());

        // artifacts location is deployment detail, not numerics
        let mut b = ExperimentConfig::default_for("ham");
        b.artifacts_root = "elsewhere".into();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn schedule_and_sync_codec_are_fingerprinted() {
        let a = ExperimentConfig::default_for("ham");
        let mut b = ExperimentConfig::default_for("ham");
        b.schedule = Policy::arrival();
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut b2 = ExperimentConfig::default_for("ham");
        b2.schedule = Policy::arrival_with_timeout(0.5, 3);
        assert_ne!(b.fingerprint(), b2.fingerprint());
        let mut c = ExperimentConfig::default_for("ham");
        c.sync_codec = Some("uniform8".into());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(c.sync_uplink_codec(0).unwrap().name(), "uniform8");
        assert_eq!(a.sync_uplink_codec(0).unwrap().name(), "identity");
        assert_eq!(a.sync_downlink_codec(1).unwrap().name(), "identity");
    }

    #[test]
    fn schedule_validation() {
        let mut c = ExperimentConfig::default_for("ham");
        c.schedule = Policy::arrival();
        c.validate().unwrap();
        c.schedule =
            Policy::ArrivalOrder { straggler_timeout_s: Some(-1.0), min_quorum: None };
        assert!(c.validate().is_err());
        c.schedule =
            Policy::ArrivalOrder { straggler_timeout_s: Some(0.5), min_quorum: Some(0) };
        assert!(c.validate().is_err());
        // quorum without a timeout is meaningless
        c.schedule = Policy::ArrivalOrder { straggler_timeout_s: None, min_quorum: Some(2) };
        assert!(c.validate().is_err());
        // quorum larger than the fleet
        c.schedule = Policy::arrival_with_timeout(0.5, 99);
        assert!(c.validate().is_err());
        c.schedule = Policy::arrival_with_timeout(0.5, 3);
        c.validate().unwrap();
        c.sync_codec = Some("bogus".into());
        assert!(c.validate().is_err());
    }

    #[test]
    fn network_heterogeneous() {
        let mut c = ExperimentConfig::default_for("ham");
        c.devices = 3;
        c.device_speeds = vec![1.0, 0.5, 2.0];
        let net = c.network();
        assert_eq!(net.devices(), 3);
        assert!(net.links[1].t_client_fwd > net.links[0].t_client_fwd);
    }
}
