//! Minimal CLI argument substrate (clap is not vendored on this image).
//!
//! Supports `--key value`, `--key=value`, bare flags, a positional
//! subcommand plus trailing positionals (file lists), with typed getters
//! that accumulate error messages so the launcher can print everything
//! wrong at once.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
    positionals_taken: bool,
    errors: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positionals.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    fn note(&mut self, key: &str) {
        if !self.known.contains(&key.to_string()) {
            self.known.push(key.to_string());
        }
    }

    pub fn str_opt(&mut self, key: &str) -> Option<String> {
        self.note(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&mut self, key: &str, default: usize) -> usize {
        self.note(key);
        match self.flags.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                self.errors.push(format!("--{key}: '{v}' is not an integer"));
                default
            }),
        }
    }

    pub fn usize_opt(&mut self, key: &str) -> Option<usize> {
        self.note(key);
        match self.flags.get(key) {
            None => None,
            Some(v) => match v.parse() {
                Ok(x) => Some(x),
                Err(_) => {
                    self.errors.push(format!("--{key}: '{v}' is not an integer"));
                    None
                }
            },
        }
    }

    pub fn f64_or(&mut self, key: &str, default: f64) -> f64 {
        self.note(key);
        match self.flags.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                self.errors.push(format!("--{key}: '{v}' is not a number"));
                default
            }),
        }
    }

    pub fn f64_opt(&mut self, key: &str) -> Option<f64> {
        self.note(key);
        match self.flags.get(key) {
            None => None,
            Some(v) => match v.parse() {
                Ok(x) => Some(x),
                Err(_) => {
                    self.errors.push(format!("--{key}: '{v}' is not a number"));
                    None
                }
            },
        }
    }

    pub fn bool_or(&mut self, key: &str, default: bool) -> bool {
        self.note(key);
        match self.flags.get(key).map(|s| s.as_str()) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => {
                self.errors.push(format!("--{key}: '{v}' is not a boolean"));
                default
            }
        }
    }

    /// Trailing positionals after the subcommand (e.g. `slacc trace`'s
    /// file list). Subcommands that don't call this get the historical
    /// "unexpected positional" error from [`Args::finish`].
    pub fn positionals(&mut self) -> Vec<String> {
        self.positionals_taken = true;
        self.positionals.clone()
    }

    /// After all getters ran: unknown flags + type errors, if any.
    pub fn finish(mut self) -> Result<(), String> {
        if !self.positionals_taken {
            for tok in &self.positionals {
                self.errors
                    .push(format!("unexpected positional argument '{tok}'"));
            }
        }
        for key in self.flags.keys() {
            if !self.known.contains(key) {
                self.errors.push(format!(
                    "unknown flag --{key} (known: {})",
                    self.known.join(", ")
                ));
            }
        }
        if self.errors.is_empty() {
            Ok(())
        } else {
            Err(self.errors.join("\n"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = parse(&["train", "--rounds", "50", "--codec=slacc", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("rounds", 10), 50);
        assert_eq!(a.str_or("codec", "x"), "slacc");
        assert!(a.bool_or("verbose", false));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse(&["train"]);
        assert_eq!(a.usize_or("rounds", 10), 10);
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
        assert!(a.f64_opt("target").is_none());
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_is_error() {
        let mut a = parse(&["--bogus", "3"]);
        let _ = a.usize_or("rounds", 1);
        assert!(a.finish().unwrap_err().contains("--bogus"));
    }

    #[test]
    fn type_error_reported() {
        let mut a = parse(&["--rounds", "abc"]);
        assert_eq!(a.usize_or("rounds", 7), 7);
        assert!(a.finish().unwrap_err().contains("not an integer"));
    }

    #[test]
    fn positionals_collect_when_consumed() {
        let mut a = parse(&["trace", "a.jsonl", "b.jsonl", "--chrome", "out.json"]);
        assert_eq!(a.subcommand.as_deref(), Some("trace"));
        assert_eq!(a.positionals(), vec!["a.jsonl", "b.jsonl"]);
        assert_eq!(a.str_opt("chrome").as_deref(), Some("out.json"));
        a.finish().unwrap();
    }

    #[test]
    fn positionals_error_when_unconsumed() {
        let a = parse(&["train", "stray.jsonl"]);
        let err = a.finish().unwrap_err();
        assert!(err.contains("unexpected positional argument 'stray.jsonl'"), "{err}");
    }

    #[test]
    fn negative_numbers_as_values() {
        let mut a = parse(&["--offset", "-3.5"]);
        // "-3.5" doesn't start with "--" so it's consumed as the value
        assert_eq!(a.f64_or("offset", 0.0), -3.5);
        a.finish().unwrap();
    }
}
