//! Cross-layer integration: the Rust coordinator against the real AOT
//! artifacts through PJRT. These are the tests that pin L3 ⇄ L2/L1 parity:
//! the Pallas entropy kernel vs the host mirror, the QDQ kernel vs the
//! Rust bit-packing quantizer, and the training-step numerics.
//!
//! Requires `make artifacts`. Each test builds its own Engine (PJRT CPU
//! client); tests are grouped coarsely to amortize compilation.

use std::path::PathBuf;

use slacc::data::Dataset;
use slacc::entropy::shannon;
use slacc::quant::linear;
use slacc::runtime::{Arg, Engine};
use slacc::tensor::Tensor;
use slacc::util::rng::Pcg32;

fn artifacts_dir(cfg: &str) -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(cfg);
    d.join("manifest.json").exists().then_some(d)
}

macro_rules! require_artifacts {
    ($cfg:expr) => {
        match artifacts_dir($cfg) {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/{} missing; run `make artifacts`", $cfg);
                return;
            }
        }
    };
}

fn random_acts(engine: &Engine, seed: u64) -> Tensor {
    let cut = engine.manifest().cut;
    let mut rng = Pcg32::seeded(seed);
    let data: Vec<f32> = (0..cut.b * cut.c * cut.h * cut.w)
        .map(|_| rng.next_gaussian().max(0.0) * rng.range_f32(0.5, 2.0))
        .collect();
    Tensor::new(cut.dims(), data)
}

/// L1 parity: the AOT Pallas entropy kernel == the Rust host mirror.
#[test]
fn pallas_entropy_kernel_matches_host_mirror() {
    let dir = require_artifacts!("ham");
    let mut engine = Engine::load(&dir).unwrap();
    for seed in [1u64, 2, 3] {
        let acts = random_acts(&engine, seed);
        let kernel = engine
            .execute("entropy", &[Arg::F32(acts.data(), acts.dims())])
            .unwrap()
            .remove(0)
            .into_data();
        let host = shannon::entropies(&acts.to_channel_major());
        assert_eq!(kernel.len(), host.len());
        for (c, (a, b)) in kernel.iter().zip(&host).enumerate() {
            assert!(
                (a - b).abs() < 1e-3,
                "seed {seed} channel {c}: kernel {a} vs host {b}"
            );
        }
        // entropies live in (0, ln N]
        let n = acts.to_channel_major().n_per_channel as f32;
        assert!(kernel.iter().all(|&h| h > 0.0 && h <= n.ln() + 1e-3));
    }
}

/// L1 parity: the AOT Pallas QDQ kernel == the Rust linear quantizer.
#[test]
fn pallas_qdq_kernel_matches_rust_quantizer() {
    let dir = require_artifacts!("ham");
    let mut engine = Engine::load(&dir).unwrap();
    let acts = random_acts(&engine, 7);
    let cm = acts.to_channel_major();
    let c = cm.channels;

    // per-channel min/max, 5-bit levels
    let bits = 5u32;
    let mut qmin = Vec::with_capacity(c);
    let mut qmax = Vec::with_capacity(c);
    for ch in 0..c {
        let (mn, mx) = slacc::tensor::view::min_max(cm.channel(ch));
        qmin.push(mn);
        qmax.push(mx);
    }
    let levels = vec![((1u32 << bits) - 1) as f32; c];
    let dims_c1 = [c, 1];

    let kernel_out = engine
        .execute(
            "qdq",
            &[
                Arg::F32(acts.data(), acts.dims()),
                Arg::F32(&qmin, &dims_c1),
                Arg::F32(&qmax, &dims_c1),
                Arg::F32(&levels, &dims_c1),
            ],
        )
        .unwrap()
        .remove(0);

    let kernel_cm = kernel_out.to_channel_major();
    for ch in 0..c {
        let rust = linear::fake_quant(cm.channel(ch), qmin[ch], qmax[ch], bits);
        for (i, (a, b)) in kernel_cm.channel(ch).iter().zip(&rust).enumerate() {
            assert!(
                (a - b).abs() <= 1e-4 + (qmax[ch] - qmin[ch]).abs() * 1e-5,
                "channel {ch} elem {i}: kernel {a} vs rust {b}"
            );
        }
    }
}

/// L2 integration: client_fwd -> server_step -> client_bwd round-trip has
/// sane shapes, finite loss, and SGD actually moves parameters; and the
/// eval_logits artifact agrees with the composed pipeline at lr=0.
#[test]
fn training_step_numerics() {
    let dir = require_artifacts!("ham");
    let mut engine = Engine::load(&dir).unwrap();
    let man = engine.manifest().clone();
    let cp = man.load_client_init().unwrap();
    let sp = man.load_server_init().unwrap();

    let (train, _) = Dataset::for_config("ham", man.batch, 1, 3).unwrap();
    let idx: Vec<usize> = (0..man.batch).collect();
    let (x, y) = train.batch(&idx);
    let x_dims = [man.batch, man.in_ch, man.img, man.img];
    let y_dims = [man.batch];

    // client forward
    let mut args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(&x, &x_dims));
    let acts = engine.execute("client_fwd", &args).unwrap().remove(0);
    assert_eq!(acts.dims(), man.cut.dims().as_slice());

    // server step at lr=0: params must not move, loss ~ ln(classes) at init
    let mut args: Vec<Arg> = sp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(acts.data(), acts.dims()));
    args.push(Arg::I32(&y, &y_dims));
    args.push(Arg::ScalarF32(0.0));
    let mut out = engine.execute("server_step", &args).unwrap();
    let new_sp = out.split_off(2);
    let g_acts = out.pop().unwrap();
    let loss = out.pop().unwrap().data()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    assert!(loss < 4.0, "init loss should be near ln(7)={:.2}, got {loss}", 7f32.ln());
    assert_eq!(g_acts.dims(), acts.dims());
    for (a, b) in sp.iter().zip(&new_sp) {
        assert_eq!(a.data(), b.data(), "lr=0 must not move server params");
    }

    // server step at lr>0 moves params and keeps loss finite
    let mut args: Vec<Arg> = sp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(acts.data(), acts.dims()));
    args.push(Arg::I32(&y, &y_dims));
    args.push(Arg::ScalarF32(0.05));
    let mut out = engine.execute("server_step", &args).unwrap();
    let new_sp = out.split_off(2);
    let moved = sp
        .iter()
        .zip(&new_sp)
        .any(|(a, b)| a.data() != b.data());
    assert!(moved, "lr=0.05 must move server params");

    // client backward at lr=0 is a no-op; with real gradient it moves
    let mut args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(&x, &x_dims));
    args.push(Arg::F32(g_acts.data(), g_acts.dims()));
    args.push(Arg::ScalarF32(0.0));
    let cp0 = engine.execute("client_bwd", &args).unwrap();
    for (a, b) in cp.iter().zip(&cp0) {
        assert_eq!(a.data(), b.data());
    }
    let mut args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(&x, &x_dims));
    args.push(Arg::F32(g_acts.data(), g_acts.dims()));
    args.push(Arg::ScalarF32(0.5));
    let cp1 = engine.execute("client_bwd", &args).unwrap();
    assert!(cp.iter().zip(&cp1).any(|(a, b)| a.data() != b.data()));

    // eval_logits == server_forward(client_forward(x)) at init params
    let mut args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    for t in &sp {
        args.push(Arg::F32(t.data(), t.dims()));
    }
    args.push(Arg::F32(&x, &x_dims));
    let logits = engine.execute("eval_logits", &args).unwrap().remove(0);
    assert_eq!(logits.dims(), &[man.batch, man.classes]);
    assert!(logits.data().iter().all(|v| v.is_finite()));
}

/// Engine argument validation: wrong shape/dtype/count are errors, not UB.
#[test]
fn engine_rejects_bad_args() {
    let dir = require_artifacts!("ham");
    let mut engine = Engine::load(&dir).unwrap();
    // wrong arg count
    assert!(engine.execute("entropy", &[]).is_err());
    // wrong dims
    let bad = vec![0.0f32; 8];
    assert!(engine
        .execute("entropy", &[Arg::F32(&bad, &[2, 2, 2, 1])])
        .is_err());
    // unknown artifact
    assert!(engine.execute("nope", &[]).is_err());
}

/// The MNIST artifact set loads and runs too (1-channel input path).
#[test]
fn mnist_artifacts_run() {
    let dir = require_artifacts!("mnist");
    let mut engine = Engine::load(&dir).unwrap();
    let man = engine.manifest().clone();
    assert_eq!(man.in_ch, 1);
    assert_eq!(man.classes, 10);
    let cp = man.load_client_init().unwrap();
    let (train, _) = Dataset::for_config("mnist", man.batch, 1, 9).unwrap();
    let idx: Vec<usize> = (0..man.batch).collect();
    let (x, _) = train.batch(&idx);
    let x_dims = [man.batch, 1, man.img, man.img];
    let mut args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    args.push(Arg::F32(&x, &x_dims));
    let acts = engine.execute("client_fwd", &args).unwrap().remove(0);
    assert_eq!(acts.dims(), man.cut.dims().as_slice());
}
