//! Cross-codec invariants (engine-free): every codec must satisfy the
//! contracts the coordinator relies on, across a randomized corpus of
//! smashed-data tensors — activation-like, gradient-like, adversarial
//! (flat channels, huge dynamic range, single elements).

use slacc::codecs::{self, compression_ratio, Codec, RoundCtx};
use slacc::entropy::shannon;
use slacc::quant::payload::Header;
use slacc::tensor::{Tensor, ChannelMajor};
use slacc::util::prop::Prop;
use slacc::util::rng::Pcg32;

fn corpus(seed: u64) -> Vec<ChannelMajor> {
    let mut rng = Pcg32::seeded(seed);
    let mut out = Vec::new();
    // activation-like (relu, varied scales)
    for &(b, c, h, w) in &[(2usize, 8usize, 4usize, 4usize), (4, 16, 8, 8), (1, 3, 2, 2)] {
        let data: Vec<f32> = (0..b * c * h * w)
            .map(|_| (rng.next_gaussian() * rng.range_f32(0.1, 3.0)).max(0.0))
            .collect();
        out.push(Tensor::new(vec![b, c, h, w], data).to_channel_major());
    }
    // gradient-like (signed, small)
    let data: Vec<f32> = (0..2 * 8 * 4 * 4).map(|_| rng.next_gaussian() * 1e-3).collect();
    out.push(Tensor::new(vec![2, 8, 4, 4], data).to_channel_major());
    // adversarial: flat channels + one huge spike
    let mut data = vec![1.0f32; 2 * 4 * 3 * 3];
    data[17] = 1e6;
    out.push(Tensor::new(vec![2, 4, 3, 3], data).to_channel_major());
    // all zeros (dead relu)
    out.push(Tensor::new(vec![1, 4, 4, 4], vec![0.0; 64]).to_channel_major());
    out
}

fn build(name: &str, channels: usize, seed: u64) -> Box<dyn Codec> {
    codecs::by_name(name, channels, 50, seed).unwrap()
}

#[test]
fn every_codec_roundtrips_every_corpus_tensor() {
    for (ti, cm) in corpus(1).into_iter().enumerate() {
        for name in codecs::ALL_CODECS {
            let mut codec = build(name, cm.channels, 2);
            let ent = shannon::entropies(&cm);
            let wire = codec.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
            let rec = codec
                .decode(&wire)
                .unwrap_or_else(|e| panic!("{name} tensor {ti}: {e}"));
            assert_eq!(rec.dims(), cm.to_nchw().dims(), "{name} tensor {ti}");
            assert!(
                rec.data().iter().all(|v| v.is_finite()),
                "{name} tensor {ti}: non-finite reconstruction"
            );
        }
    }
}

#[test]
fn repeated_rounds_keep_state_consistent() {
    // stateful codecs (slacc ACII history, randtopk RNG) must stay valid
    // over many rounds with changing inputs
    let mut rng = Pcg32::seeded(3);
    for name in ["slacc", "slacc-paper-eq6", "randtopk"] {
        let mut codec = build(name, 8, 4);
        for round in 0..30 {
            let data: Vec<f32> = (0..2 * 8 * 4 * 4)
                .map(|_| rng.next_gaussian() * (1.0 + round as f32))
                .collect();
            let cm = Tensor::new(vec![2, 8, 4, 4], data).to_channel_major();
            let wire = codec.compress(&cm, RoundCtx::default());
            let rec = codec.decode(&wire).unwrap();
            assert!(rec.data().iter().all(|v| v.is_finite()), "{name} round {round}");
        }
    }
}

#[test]
fn quantizing_codecs_bound_reconstruction_error() {
    // all min/max-linear codecs: |err| <= range at their worst bit width
    Prop::new("codec error bounded by channel range")
        .cases(40)
        .max_size(12)
        .run(|rng, size| {
            let (b, c, h, w) = (2usize, (size % 8) + 2, 4usize, 4usize);
            let data: Vec<f32> = (0..b * c * h * w)
                .map(|_| rng.next_gaussian() * 2.0)
                .collect();
            let cm = Tensor::new(vec![b, c, h, w], data).to_channel_major();
            let orig = cm.to_nchw();
            for name in ["slacc", "uniform4", "uniform8", "easyquant", "powerquant"] {
                let mut codec = build(name, c, rng.next_u64());
                let wire = codec.compress(&cm, RoundCtx::default());
                let rec = codec.decode(&wire).map_err(|e| format!("{name}: {e}"))?;
                let orig_cm = orig.to_channel_major();
                let rec_cm = rec.to_channel_major();
                for ch in 0..c {
                    let (mn, mx) = slacc::tensor::view::min_max(orig_cm.channel(ch));
                    // group-wide ranges can exceed per-channel range; bound
                    // by the global tensor range to stay codec-agnostic
                    let (gmn, gmx) = slacc::tensor::view::min_max(orig.data());
                    let bound = ((mx - mn).max(gmx - gmn) / 3.0).max(1e-4) * 1.01;
                    for (a, v) in orig_cm.channel(ch).iter().zip(rec_cm.channel(ch)) {
                        if (a - v).abs() > bound {
                            return Err(format!(
                                "{name} ch {ch}: err {} > {bound}",
                                (a - v).abs()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
}

#[test]
fn compression_ratios_ordered_sanely() {
    // on large activation tensors: identity < uniform8 < uniform4 wire size;
    // slacc between b_min and b_max equivalents
    let mut rng = Pcg32::seeded(9);
    let data: Vec<f32> = (0..16 * 32 * 8 * 8).map(|_| rng.next_gaussian().max(0.0)).collect();
    let cm = Tensor::new(vec![16, 32, 8, 8], data).to_channel_major();
    let wire = |name: &str| {
        let mut c = build(name, 32, 10);
        c.compress(&cm, RoundCtx::default()).len()
    };
    let id = wire("identity");
    let u8b = wire("uniform8");
    let u4b = wire("uniform4");
    let sl = wire("slacc");
    assert!(u8b < id && u4b < u8b, "id {id} u8 {u8b} u4 {u4b}");
    // slacc: 2..8 bits -> wire between uniform2-ish and uniform8
    assert!(sl <= u8b + 1024, "slacc {sl} vs u8 {u8b}");
    assert!(compression_ratio(&cm, sl) >= 4.0, "slacc ratio too low");
}

/// Every registered spec the hostile-envelope fuzz drives (base families,
/// a wrapped spec, and a parameterized selection spec).
const FUZZ_SPECS: &[&str] = &[
    "identity", "uniform4", "uniform8", "slacc", "slacc-paper-eq6",
    "powerquant", "randtopk", "splitfc", "easyquant", "ef:uniform4",
    "select:std:2",
];

#[test]
fn corrupted_payloads_never_panic() {
    // decode is exposed to the network; any byte corruption must be a
    // clean Err (or a well-formed wrong tensor), never a panic/OOB
    let cm = corpus(11).remove(1);
    for name in FUZZ_SPECS {
        let mut codec = build(name, cm.channels, 12);
        let wire = codec.compress(&cm, RoundCtx::default());
        // bit flips anywhere in the body
        let mut rng = Pcg32::seeded(13);
        for _ in 0..50 {
            let mut bad = wire.clone();
            let pos = rng.below(bad.len() as u32) as usize;
            bad[pos] ^= 1 << rng.below(8);
            let _ = codec.decode(&bad); // must not panic
        }
    }
}

#[test]
fn hostile_envelopes_systematically_rejected() {
    // For every registered codec: every prefix truncation of a valid
    // envelope, and every bit flip in its payload header, must come back
    // as a typed CodecError — never a panic, and never an allocation past
    // the MAX_ELEMENTS guard (the hostile-dims case below would demand
    // terabytes if any decoder allocated from dims before validating).
    let cm = corpus(21).remove(0); // (2, 8, 4, 4) activation-like
    for name in FUZZ_SPECS {
        let mut codec = build(name, cm.channels, 22);
        let wire = codec.compress(&cm, RoundCtx::default());
        codec
            .decode(&wire)
            .unwrap_or_else(|e| panic!("{name}: pristine envelope rejected: {e}"));

        // every strict prefix fails cleanly (decoders consume an exact,
        // self-described byte count and reject both shortfall and surplus)
        for cut in 0..wire.len() {
            assert!(
                codec.decode(&wire[..cut]).is_err(),
                "{name}: accepted a {cut}-byte prefix of a {}-byte envelope",
                wire.len()
            );
        }

        // every bit flip in the common payload header (magic, codec id,
        // version, dims)
        for byte in 0..Header::BYTES {
            for bit in 0..8 {
                let mut bad = wire.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    codec.decode(&bad).is_err(),
                    "{name}: accepted a header flip at byte {byte} bit {bit}"
                );
            }
        }

        // hostile dims: a header claiming terabytes must be rejected by
        // the MAX_ELEMENTS guard before any allocation happens
        let mut bad = wire.clone();
        for (i, d) in [60000u32, 60000, 60000, 4].into_iter().enumerate() {
            bad[4 + 4 * i..8 + 4 * i].copy_from_slice(&d.to_le_bytes());
        }
        assert!(codec.decode(&bad).is_err(), "{name}: hostile dims accepted");
    }
}

#[test]
fn slacc_adapts_bits_to_entropy_structure() {
    // construct data where half the channels are informative (high variance
    // textured) and half are near-flat; with external entropy ranking the
    // informative half higher, slacc must allocate them more bits
    let (b, c, h, w) = (2usize, 8usize, 8usize, 8usize);
    let mut rng = Pcg32::seeded(14);
    let mut data = vec![0.0f32; b * c * h * w];
    for bi in 0..b {
        for ch in 0..c {
            for i in 0..h * w {
                let idx = (bi * c + ch) * h * w + i;
                data[idx] = if ch < 4 {
                    rng.next_gaussian() // informative
                } else {
                    0.01 * (i % 2) as f32 // near-flat
                };
            }
        }
    }
    let cm = Tensor::new(vec![b, c, h, w], data).to_channel_major();
    let ent: Vec<f32> = (0..c).map(|ch| if ch < 4 { 8.0 } else { 2.0 }).collect();

    let mut codec = slacc::codecs::slacc::SlAccCodec::new(
        slacc::codecs::slacc::SlAccConfig { groups: 2, ..Default::default() },
        c,
        50,
        15,
    );
    let _ = codec.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
    let last = codec.last_round().unwrap();
    let g_hi = last.group_of_channel[0];
    let g_lo = last.group_of_channel[7];
    assert_ne!(g_hi, g_lo);
    assert!(last.group_bits[g_hi] > last.group_bits[g_lo]);
}
