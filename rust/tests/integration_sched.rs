//! Scheduler-subsystem integration: the three scheduling policies end to
//! end, over the deterministic loopback delay shim and over real sockets
//! behind the poll-driven event loop.
//!
//! Load-bearing properties:
//! * `InOrder` stays byte-for-byte identical across policies at zero delay
//!   (the PR 1 parity goldens keep holding — see integration_transport.rs,
//!   whose TCP paths now run through the poll event loop).
//! * `ArrivalOrder` is deterministic under the seeded artificial-delay
//!   shim.
//! * A straggler timeout + quorum closes rounds without the slow device
//!   and carries it over; the carried device's stale work is served when
//!   it lands.
//! * One single-threaded poll loop sustains ≥ 64 concurrent mock-compute
//!   device connections.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::data::Dataset;
use slacc::sched::event_loop::FleetOptions;
use slacc::sched::poll::Backend;
use slacc::sched::soak::{run_churn_soak, run_soak, ChurnSoakConfig, SoakConfig};
use slacc::sched::Policy;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::proto::Message;
use slacc::transport::server::{
    accept_and_serve, mock_runtime, run_mock_loopback, run_mock_loopback_churn,
    run_mock_loopback_delayed,
};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::{DelayedTransport, Transport};

fn tiny_cfg(codec: &str, devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64;
    cfg.test_n = 16;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named(codec.into());
    cfg
}

#[test]
fn arrival_order_at_zero_delay_matches_inorder_exactly() {
    // with no artificial delay, arrival order degenerates to id order, so
    // the two policies must agree on every number — this pins the
    // scheduler refactor to the PR 1 baseline
    let cfg = tiny_cfg("slacc", 3, 4);
    let inorder = run_mock_loopback(&cfg).unwrap();
    let mut cfg2 = tiny_cfg("slacc", 3, 4);
    cfg2.schedule = Policy::arrival();
    let arrival = run_mock_loopback(&cfg2).unwrap();
    assert_eq!(inorder.metrics.len(), arrival.metrics.len());
    for (a, b) in inorder.metrics.records.iter().zip(&arrival.metrics.records) {
        assert_eq!(a.loss, b.loss, "round {}", a.round);
        assert_eq!(a.bytes_up, b.bytes_up, "round {}", a.round);
        assert_eq!(a.bytes_down, b.bytes_down, "round {}", a.round);
        assert_eq!(a.bytes_sync, b.bytes_sync, "round {}", a.round);
        assert_eq!(a.accuracy, b.accuracy, "round {}", a.round);
    }
    assert_eq!(arrival.straggler_events, 0);
}

#[test]
fn arrival_order_is_deterministic_under_the_delay_shim() {
    let mut cfg = tiny_cfg("slacc", 3, 4);
    cfg.schedule = Policy::arrival();
    let delays = [0.03, 0.01, 0.02];
    let (a, sched_a) = run_mock_loopback_delayed(&cfg, &delays, 42).unwrap();
    let (b, sched_b) = run_mock_loopback_delayed(&cfg, &delays, 42).unwrap();
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.loss, y.loss, "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
        assert_eq!(x.accuracy, y.accuracy, "round {}", x.round);
    }
    assert_eq!(sched_a, sched_b, "scheduling records must be reproducible");
    assert_eq!(a.rounds_run, 4);
    // no timeout configured: everyone participates every round
    for rec in &sched_a {
        assert_eq!(rec.participants.len(), 3, "round {}", rec.round);
        assert!(rec.stragglers.is_empty(), "round {}", rec.round);
    }
}

#[test]
fn quorum_close_carries_the_straggler_and_serves_its_stale_work() {
    let mut cfg = tiny_cfg("slacc", 3, 10);
    cfg.eval_every = 20; // eval only at the end
    cfg.schedule = Policy::arrival_with_timeout(0.4, 2);
    // device 2 is ~20x slower than the 0.4 s timeout window allows
    let delays = [0.06, 0.06, 1.2];
    let (report, sched) = run_mock_loopback_delayed(&cfg, &delays, 7).unwrap();
    assert_eq!(report.rounds_run, 10);
    assert!(report.straggler_events >= 1, "no straggler was ever carried");

    // round 0 must close on the timeout with exactly the fast quorum
    // (participants are in arrival order, so compare as a set)
    let r0 = &sched[0];
    let mut p0 = r0.participants.clone();
    p0.sort_unstable();
    assert_eq!(p0, vec![0, 1]);
    assert_eq!(r0.stragglers, vec![2]);
    assert!((r0.wait_s[2] - 0.4).abs() < 1e-6, "straggler wait = timeout burn");

    // the carried device's stale round-0 Activations must land and be
    // served in some later round (virtual arrival ~1.2 s, session ~1.5 s)
    assert!(
        sched.iter().any(|r| r.stale.contains(&2)),
        "straggler never caught up: {sched:?}"
    );
    // fast devices keep making progress every round
    for rec in &sched {
        assert!(!rec.participants.is_empty(), "round {} had no participants", rec.round);
    }
}

#[test]
fn unmet_quorum_blocks_the_close_until_the_slow_device_arrives() {
    let mut cfg = tiny_cfg("slacc", 3, 3);
    cfg.eval_every = 10;
    // quorum == fleet size: the timeout alone may never drop anyone
    cfg.schedule = Policy::arrival_with_timeout(0.2, 3);
    let delays = [0.0, 0.0, 3.0];
    let (report, sched) = run_mock_loopback_delayed(&cfg, &delays, 7).unwrap();
    assert_eq!(report.rounds_run, 3);
    // nobody is ever *dropped* — the quorum requires the whole fleet
    assert_eq!(report.straggler_events, 0);
    // round 0 blocked past the timeout until the slow device delivered
    assert_eq!(sched[0].participants.len(), 3);
    assert!(sched[0].wait_s[2] > 2.0, "slow device wait not recorded");
    // its ModelSync push is still in flight afterwards, so later rounds
    // proceed with the fast pair while it finishes the handoff
    for rec in &sched[1..] {
        assert!(rec.participants.len() >= 2, "round {}", rec.round);
        assert!(rec.stragglers.is_empty(), "round {}", rec.round);
    }
}

#[test]
fn modelsync_bytes_ride_their_own_axis_and_compress() {
    // default (identity) sync stream: lossless, but accounted
    let cfg = tiny_cfg("slacc", 3, 4);
    let report = run_mock_loopback(&cfg).unwrap();
    assert!(report.total_bytes_sync > 0, "sync traffic must be accounted");
    for rec in &report.metrics.records {
        // agg_every=1: every round pushes + broadcasts sub-models
        assert!(rec.bytes_sync > 0, "round {}", rec.round);
        assert!(rec.bytes_up > 0 && rec.bytes_down > 0);
    }
    // a lossy sync codec runs end to end and changes the sync byte count
    let mut cfg2 = tiny_cfg("slacc", 3, 4);
    cfg2.sync_codec = Some("uniform8".into());
    let lossy = run_mock_loopback(&cfg2).unwrap();
    assert_eq!(lossy.rounds_run, 4);
    assert!(lossy.metrics.records.iter().all(|r| r.loss.is_finite()));
    assert!(lossy.total_bytes_sync > 0);
    assert_ne!(
        lossy.total_bytes_sync, report.total_bytes_sync,
        "sync codec choice must be visible in the sync byte axis"
    );
    // smashed-data axes are untouched by the sync codec choice
    assert_eq!(report.total_bytes_up, lossy.total_bytes_up);
    assert_eq!(report.total_bytes_down, lossy.total_bytes_down);
}

/// ≥ 64 concurrent mock-compute devices against the single-threaded poll
/// loop (the acceptance bar for the event-loop server).
#[test]
fn poll_server_sustains_64_concurrent_connections() {
    let devices = 64;
    let mut cfg = tiny_cfg("uniform4", devices, 2);
    cfg.train_n = 256;
    cfg.eval_every = 10;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    let report = accept_and_serve(&mut rt, &listener).unwrap();
    assert_eq!(report.rounds_run, 2);
    for (d, h) in handles.into_iter().enumerate() {
        h.join().unwrap().unwrap_or_else(|e| panic!("device {d}: {e}"));
    }
}

/// TCP integration: arrival-order + straggler timeout against a device
/// that is 3x slower than the whole session should take. The fleet must
/// complete every round without serializing on it.
#[test]
fn tcp_arrival_order_does_not_block_on_a_slow_device() {
    let devices = 3;
    let rounds = 4;
    let slow = Duration::from_millis(300);
    let mut cfg = tiny_cfg("slacc", devices, rounds);
    cfg.eval_every = 10;
    cfg.schedule = Policy::arrival_with_timeout(0.1, 2);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let inner =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))?;
            if d == devices - 1 {
                let mut conn = DelayedTransport::slow_activations(inner, slow);
                run_blocking(&mut worker, &mut conn)
            } else {
                let mut conn = inner;
                run_blocking(&mut worker, &mut conn)
            }
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    let t0 = Instant::now();
    let report = accept_and_serve(&mut rt, &listener).unwrap();
    let wall = t0.elapsed();
    assert_eq!(report.rounds_run, rounds);
    assert!(report.straggler_events >= 1, "slow device was never carried");
    // in-order would serialize on the slow device: >= rounds * 300 ms.
    // arrival order pays at most ~one timeout per round.
    let blocking_floor = slow * rounds as u32;
    assert!(
        wall < blocking_floor,
        "event loop blocked on the straggler: {wall:?} >= {blocking_floor:?}"
    );
    // the slow device may exit Ok (buffered Shutdown) or with a closed
    // socket, depending on timing; the fast devices must finish cleanly
    for (d, h) in handles.into_iter().enumerate() {
        let out = h.join().unwrap();
        if d < devices - 1 {
            out.unwrap_or_else(|e| panic!("device {d}: {e}"));
        }
    }
}

/// A device that vanishes mid-session must surface as a typed peer-closed
/// transport error, failing the session cleanly rather than hanging —
/// under BOTH scheduling policies (arrival order waits in `recv_any`,
/// which must also notice dead sockets).
fn run_mid_session_disconnect(schedule: Policy) {
    let devices = 2;
    let mut cfg = tiny_cfg("slacc", devices, 50);
    cfg.eval_every = 100;
    cfg.schedule = schedule;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)
                    .unwrap();
            let mut worker = mock_worker(&cfg, Arc::new(train), d).unwrap();
            let mut conn =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))
                    .unwrap();
            if d == 1 {
                // play two rounds then vanish
                conn.send(&worker.hello()).unwrap();
                let mut seen = 0;
                while seen < 5 {
                    let msg = conn.recv().unwrap();
                    let rounds_seen = matches!(msg, Message::RoundOpen { .. });
                    for reply in worker.handle(msg).unwrap() {
                        conn.send(&reply).unwrap();
                    }
                    if rounds_seen {
                        seen += 1;
                    }
                }
                drop(conn);
            } else {
                let _ = run_blocking(&mut worker, &mut conn);
            }
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    // FIN-vs-RST timing decides whether the EOF or a reset surfaces first;
    // either way the session fails promptly with a connection-level error
    // (the PeerClosed *typing* itself is pinned by the tcp.rs unit tests)
    assert!(
        err.contains("peer closed") || err.contains("i/o error"),
        "want a connection-level failure, got: {err}"
    );
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn mid_session_disconnect_fails_with_peer_closed_inorder() {
    run_mid_session_disconnect(Policy::InOrder);
}

#[test]
fn mid_session_disconnect_fails_with_peer_closed_arrival() {
    run_mid_session_disconnect(Policy::arrival());
}

fn soak_backends() -> Vec<Backend> {
    if cfg!(target_os = "linux") {
        vec![Backend::Epoll, Backend::Poll]
    } else {
        vec![Backend::Poll]
    }
}

/// 1024 real TCP device connections through one single-threaded event
/// loop, on every readiness backend, with byte-for-byte parity: every
/// device's wire accounting must be identical — across devices, across
/// backends, and against a 64-device reference fleet. This is the scale
/// acceptance bar for the epoll rework (the backend must change *nothing*
/// but the wakeup mechanics).
#[test]
fn scale_soak_1024_devices_with_byte_parity_across_backends() {
    let rounds = 3;
    let mut ref_cfg = SoakConfig::new(64, rounds);
    ref_cfg.opts = FleetOptions { backend: Backend::Poll, write_stall_secs: 10, elastic: false };
    let reference = run_soak(&ref_cfg).expect("64-device reference soak");
    let golden = reference.per_device[0];
    for stats in &reference.per_device {
        assert_eq!(*stats, golden, "reference fleet traffic must be uniform");
    }
    for backend in soak_backends() {
        let mut cfg = SoakConfig::new(1024, rounds);
        cfg.driver_threads = 8;
        cfg.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
        let report = run_soak(&cfg)
            .unwrap_or_else(|e| panic!("1024-device soak on {backend:?}: {e}"));
        assert_eq!(report.backend, backend.as_str());
        assert_eq!(report.per_device.len(), 1024);
        for (d, stats) in report.per_device.iter().enumerate() {
            assert_eq!(
                *stats, golden,
                "device {d} on {backend:?} diverged from the 64-device reference"
            );
        }
    }
}

/// One device stops reading its downlink for 1.5 s while the server owes
/// it a frame bigger than the socket buffers: the send must park on
/// POLLOUT (not abort — the stall budget is 10 s), the fleet must finish
/// the session, and the slow device's wire accounting must come out
/// identical to everyone else's.
#[test]
fn slow_reader_backpressure_recovers_at_scale() {
    for backend in soak_backends() {
        let mut cfg = SoakConfig::new(128, 2);
        // 512 KiB downlink overflows loopback socket buffers, so the
        // write to the sleeping reader genuinely parks
        cfg.down_bytes = 512 * 1024;
        cfg.slow_reader = Some((5, 1500));
        cfg.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
        let report = run_soak(&cfg)
            .unwrap_or_else(|e| panic!("backpressure soak on {backend:?}: {e}"));
        assert!(
            report.wall_s >= 1.0,
            "slow reader never backed the writer up (wall {:.2}s)",
            report.wall_s
        );
        let golden = report.per_device[0];
        for (d, stats) in report.per_device.iter().enumerate() {
            assert_eq!(*stats, golden, "device {d} diverged under backpressure");
        }
    }
}

/// Elastic-membership acceptance: a 16-device session with 4 scripted
/// departures (two graceful `Leave`s, two abrupt hang-ups, all with the
/// server's RoundOpen already delivered and the device's reply unsent)
/// and 2 re-admissions through the proto-v6 Join/JoinAck/Catchup
/// handshake — on every readiness backend. Per-device wire accounting
/// must match the script-derived frame counts exactly and be
/// byte-for-byte identical across backends.
#[test]
fn churn_soak_16_devices_with_byte_parity_across_backends() {
    let mut reports = Vec::new();
    for backend in soak_backends() {
        let mut base = SoakConfig::new(16, 6);
        base.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
        let cfg = ChurnSoakConfig {
            base,
            kills: vec![(1, 3, true), (2, 7, false), (3, 11, true), (2, 14, false)],
            rejoins: vec![(3, 3), (4, 7)],
        };
        let report = run_churn_soak(&cfg)
            .unwrap_or_else(|e| panic!("churn soak on {backend:?}: {e}"));
        assert_eq!(report.backend, backend.as_str());
        assert_eq!(
            report.departures,
            vec![(3, true), (7, false), (11, true), (14, false)],
            "departure log on {backend:?}"
        );
        for d in 0..16 {
            let (sent, recv) = cfg.expected_frames(d);
            let stats = report.per_device[d];
            assert_eq!(stats.frames_sent, sent, "device {d} frames sent on {backend:?}");
            assert_eq!(stats.frames_recv, recv, "device {d} frames recv on {backend:?}");
        }
        reports.push(report);
    }
    let first = &reports[0];
    for other in &reports[1..] {
        for d in 0..16 {
            assert_eq!(
                other.per_device[d], first.per_device[d],
                "device {d}: wire accounting diverged between {} and {}",
                first.backend, other.backend
            );
        }
    }
}

/// The scheduler-level elastic path over the in-process loopback fleet:
/// scripted kills shrink the participant set at round boundaries, a
/// re-joining device is admitted with JoinAck + model catchup and trains
/// again, and the whole churned session is deterministic end to end.
#[test]
fn elastic_loopback_absorbs_churn_and_readmits() {
    let mut cfg = tiny_cfg("slacc", 4, 8);
    cfg.eval_every = 100;
    cfg.elastic = true;
    cfg.schedule = Policy::arrival();
    let kills = [(2, 1), (3, 3)];
    let rejoins = [(5, 1)];
    let (report, sched) = run_mock_loopback_churn(&cfg, &kills, &rejoins).unwrap();
    assert_eq!(report.rounds_run, 8);
    assert!(report.metrics.records.iter().all(|r| r.loss.is_finite()));
    let sizes: Vec<usize> = sched.iter().map(|r| r.participants.len()).collect();
    assert_eq!(sizes, vec![4, 4, 3, 2, 2, 3, 3, 3], "participant counts per round");
    assert!(!sched[2].participants.contains(&1), "device 1 departed at round 2");
    assert!(sched[5].participants.contains(&1), "device 1 re-admitted at round 5");
    assert!(!sched[5].participants.contains(&3), "device 3 stayed departed");
    // the same churn script reproduces the same session, number for number
    let (again, sched2) = run_mock_loopback_churn(&cfg, &kills, &rejoins).unwrap();
    assert_eq!(report.metrics.len(), again.metrics.len());
    for (a, b) in report.metrics.records.iter().zip(&again.metrics.records) {
        assert_eq!(a.loss, b.loss, "round {}", a.round);
        assert_eq!(a.bytes_up, b.bytes_up, "round {}", a.round);
        assert_eq!(a.bytes_down, b.bytes_down, "round {}", a.round);
    }
    assert_eq!(sched, sched2, "scheduling records must be reproducible under churn");
}

/// The full 10k-devices-per-shard target. 10 000 device sockets plus their
/// client ends need ~20 100 file descriptors, beyond most default rlimits,
/// so this runs only on demand:
/// `ulimit -n 24576 && cargo test --release -- --ignored scale_soak_10k`
#[test]
#[ignore = "needs ~20k fds (ulimit -n 24576) and several minutes"]
fn scale_soak_10k_devices() {
    let rounds = 1;
    let mut ref_cfg = SoakConfig::new(64, rounds);
    ref_cfg.opts = FleetOptions { backend: Backend::Poll, write_stall_secs: 10, elastic: false };
    let golden = run_soak(&ref_cfg).expect("64-device reference soak").per_device[0];
    for backend in soak_backends() {
        let mut cfg = SoakConfig::new(10_000, rounds);
        cfg.driver_threads = 16;
        cfg.opts = FleetOptions { backend, write_stall_secs: 30, elastic: false };
        let report = run_soak(&cfg)
            .unwrap_or_else(|e| panic!("10k-device soak on {backend:?}: {e}"));
        assert_eq!(report.per_device.len(), 10_000);
        for (d, stats) in report.per_device.iter().enumerate() {
            assert_eq!(*stats, golden, "device {d} on {backend:?} diverged");
        }
    }
}
