//! Batched server compute — the equivalence suite.
//!
//! Load-bearing properties of `--batch-window`:
//! * `server_step_batch` on the mock compute IS the sequential chain, bit
//!   for bit (pinned again here at the session level; the compute-level
//!   pin lives in `transport/compute.rs`).
//! * A batched arrival-order session matches its `--batch-window 1` twin
//!   on every loss bit, every byte axis, and every scheduling record —
//!   batching may only change how many dispatches the steps ride in.
//! * InOrder forces batch=1 (message-for-message parity with the
//!   pre-batching baseline is its contract).
//! * Loopback and TCP agree byte-for-byte with `--batch-window 8`.
//! * Straggler/quorum rounds batch only the devices actually present.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::metrics::TrainReport;
use slacc::data::Dataset;
use slacc::sched::Policy;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{
    accept_and_serve, mock_runtime, run_mock_loopback, run_mock_loopback_delayed,
};
use slacc::transport::tcp::TcpTransport;

fn tiny_cfg(codec: &str, devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64.max(devices * 8);
    cfg.test_n = 16;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named(codec.into());
    cfg
}

fn assert_records_identical(a: &TrainReport, b: &TrainReport) {
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
        assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
        assert_eq!(x.bytes_sync, y.bytes_sync, "round {}", x.round);
        assert_eq!(x.raw_up, y.raw_up, "round {}", x.round);
        assert_eq!(x.accuracy, y.accuracy, "round {}", x.round);
        assert_eq!(x.participants, y.participants, "round {}", x.round);
    }
}

#[test]
fn batched_arrival_session_matches_window1_bit_for_bit() {
    let mut base = tiny_cfg("slacc", 4, 4);
    base.schedule = Policy::arrival();
    let baseline = run_mock_loopback(&base).unwrap();
    assert_eq!(
        baseline.server_dispatches, baseline.server_steps,
        "window 1 = one dispatch per device step"
    );
    for window in [2usize, 8] {
        let mut cfg = tiny_cfg("slacc", 4, 4);
        cfg.schedule = Policy::arrival();
        cfg.batch_window = window;
        let batched = run_mock_loopback(&cfg).unwrap();
        assert_records_identical(&baseline, &batched);
        assert_eq!(batched.server_steps, baseline.server_steps);
        assert!(
            batched.server_dispatches < batched.server_steps,
            "window {window}: no dispatch was ever amortized \
             ({} dispatches for {} steps)",
            batched.server_dispatches,
            batched.server_steps
        );
    }
}

#[test]
fn batched_sessions_with_delays_stay_deterministic() {
    let mut cfg = tiny_cfg("slacc", 3, 4);
    cfg.schedule = Policy::arrival();
    cfg.batch_window = 4;
    let delays = [0.03, 0.01, 0.02];
    let (a, sched_a) = run_mock_loopback_delayed(&cfg, &delays, 42).unwrap();
    let (b, sched_b) = run_mock_loopback_delayed(&cfg, &delays, 42).unwrap();
    assert_records_identical(&a, &b);
    assert_eq!(sched_a, sched_b);
    assert_eq!(a.server_dispatches, b.server_dispatches);
}

#[test]
fn inorder_forces_single_item_dispatches() {
    // InOrder's determinism contract precludes coalescing: a window of 8
    // must behave exactly like (and dispatch exactly like) window 1
    let baseline = run_mock_loopback(&tiny_cfg("slacc", 3, 4)).unwrap();
    let mut cfg = tiny_cfg("slacc", 3, 4);
    cfg.batch_window = 8;
    let windowed = run_mock_loopback(&cfg).unwrap();
    assert_records_identical(&baseline, &windowed);
    assert_eq!(windowed.server_dispatches, windowed.server_steps);
    assert_eq!(windowed.server_steps, 3 * 4);
}

#[test]
fn quorum_close_batches_only_the_devices_present() {
    let mut cfg = tiny_cfg("slacc", 3, 10);
    cfg.eval_every = 20;
    cfg.schedule = Policy::arrival_with_timeout(0.4, 2);
    cfg.batch_window = 8;
    // device 2 misses every 0.4 s window; rounds must close on the fast
    // pair and batch exactly them (plus the straggler's stale catch-ups)
    let delays = [0.06, 0.06, 1.2];
    let (report, sched) = run_mock_loopback_delayed(&cfg, &delays, 7).unwrap();
    assert_eq!(report.rounds_run, 10);
    assert!(report.straggler_events >= 1, "no straggler was ever carried");
    assert!(
        sched.iter().any(|r| r.stale.contains(&2)),
        "straggler never caught up: {sched:?}"
    );
    // every Activations that arrived was stepped (none were dropped or
    // double-stepped by the batcher)
    let arrived: usize = sched.iter().map(|r| r.participants.len() + r.stale.len()).sum();
    assert_eq!(report.server_steps, arrived);
    // the fast pair coalesces: fewer dispatches than steps
    assert!(
        report.server_dispatches < report.server_steps,
        "{} dispatches for {} steps",
        report.server_dispatches,
        report.server_steps
    );
    // identical runs of the same quorum session at window 1 agree on the
    // numbers (the batcher changes dispatch count only)
    let mut w1 = cfg.clone();
    w1.batch_window = 1;
    let (base, sched1) = run_mock_loopback_delayed(&w1, &delays, 7).unwrap();
    assert_records_identical(&base, &report);
    assert_eq!(sched1, sched);
}

/// Loopback vs TCP byte parity at `--batch-window 8`: the mock model is
/// arrival-order-independent in its *bytes* (gradients don't read the
/// server params), so per-round byte axes must agree across transports
/// even though TCP arrival order is racy.
#[test]
fn tcp_vs_loopback_byte_parity_with_batch_window_8() {
    let mut cfg = tiny_cfg("slacc", 3, 4);
    cfg.schedule = Policy::arrival();
    cfg.batch_window = 8;

    let loopback = run_mock_loopback(&cfg).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..cfg.devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    let tcp = accept_and_serve(&mut rt, &listener).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    assert_eq!(tcp.rounds_run, loopback.rounds_run);
    assert_eq!(tcp.server_steps, loopback.server_steps);
    for (a, b) in loopback.metrics.records.iter().zip(&tcp.metrics.records) {
        assert_eq!(a.bytes_up, b.bytes_up, "round {}", a.round);
        assert_eq!(a.bytes_down, b.bytes_down, "round {}", a.round);
        assert_eq!(a.bytes_sync, b.bytes_sync, "round {}", a.round);
        assert_eq!(a.raw_up, b.raw_up, "round {}", a.round);
        assert_eq!(a.raw_down, b.raw_down, "round {}", a.round);
    }
    assert_eq!(
        (loopback.total_bytes_up, loopback.total_bytes_down, loopback.total_bytes_sync),
        (tcp.total_bytes_up, tcp.total_bytes_down, tcp.total_bytes_sync)
    );
}

/// A fleet whose members disagree on `--batch-window` must be rejected at
/// handshake (an engine session's fused batched update changes numerics).
#[test]
fn mismatched_batch_window_rejected_at_handshake() {
    let mut server_cfg = tiny_cfg("slacc", 1, 2);
    server_cfg.schedule = Policy::arrival();
    server_cfg.batch_window = 8;
    let mut device_cfg = server_cfg.clone();
    device_cfg.batch_window = 1;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = thread::spawn(move || {
        let (train, _) = Dataset::for_config(
            &device_cfg.dataset,
            device_cfg.train_n,
            device_cfg.test_n,
            device_cfg.seed,
        )
        .unwrap();
        let mut worker = mock_worker(&device_cfg, Arc::new(train), 0).unwrap();
        let mut conn =
            TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100)).unwrap();
        // the server drops the session at handshake; any outcome but a
        // clean full run is acceptable on the device side
        let _ = run_blocking(&mut worker, &mut conn);
    });
    let (_, test) = Dataset::for_config(
        &server_cfg.dataset,
        server_cfg.train_n,
        server_cfg.test_n,
        server_cfg.seed,
    )
    .unwrap();
    let mut rt = mock_runtime(&server_cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    assert!(
        err.contains("fingerprint"),
        "want a session-fingerprint rejection, got: {err}"
    );
    handle.join().unwrap();
}
