//! Failure-injection and edge-case robustness (engine-free).
//!
//! The coordinator must fail *cleanly* — typed errors, no panics — on
//! corrupted artifacts, malformed manifests, adversarial payloads, and
//! degenerate configurations.

use std::fs;
use std::path::PathBuf;

use slacc::codecs::{self, Codec, RoundCtx};
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::data::loader::BatchLoader;
use slacc::data::partition::{label_skew, partition, Partition};
use slacc::data::{synth_ham, synth_mnist, Dataset};
use slacc::net::{DeviceLink, NetworkSim, ServerModel};
use slacc::runtime::artifacts::Manifest;
use slacc::tensor::Tensor;
use slacc::util::rng::Pcg32;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("slacc_rob_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------------
// manifest / artifact corruption
// ---------------------------------------------------------------------

#[test]
fn manifest_missing_file_is_error() {
    let d = tmpdir("missing");
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_corrupt_json_is_error() {
    let d = tmpdir("corrupt");
    fs::write(d.join("manifest.json"), "{ not json !!!").unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(err.contains("manifest.json"), "{err}");
}

#[test]
fn manifest_wrong_schema_is_error() {
    let d = tmpdir("schema");
    fs::write(
        d.join("manifest.json"),
        r#"{"schema": 999, "config": {}, "artifacts": {}}"#,
    )
    .unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(err.contains("schema"), "{err}");
}

#[test]
fn manifest_missing_keys_is_error_not_panic() {
    let d = tmpdir("keys");
    fs::write(
        d.join("manifest.json"),
        r#"{"schema": 1, "config": {"name": "x"}, "artifacts": {}}"#,
    )
    .unwrap();
    // missing cut/in_ch/... must surface as Err (json `at` panics are
    // caught at the std::panic boundary only in tests; Manifest uses
    // Result paths for the top-level keys it reads with ok_or)
    let res = std::panic::catch_unwind(|| Manifest::load(&d));
    match res {
        Ok(r) => assert!(r.is_err()),
        Err(_) => {} // a panic from a deliberately-truncated manifest is
                     // still contained to load time, never training time
    }
}

#[test]
fn param_blob_size_mismatch_is_error() {
    // build a minimal valid manifest with one artifact-free param spec
    let d = tmpdir("blob");
    fs::write(
        d.join("manifest.json"),
        r#"{"schema":1,
            "config":{"name":"t","in_ch":1,"classes":2,"batch":2,"img":8,
                      "cut":{"b":2,"c":4,"h":4,"w":4,"n_per_channel":32},
                      "gn_groups":2,"seed":0},
            "client_params":[{"name":"w","dims":[4],"offset":0,"size":4}],
            "server_params":[],
            "client_param_count":4,"server_param_count":0,
            "artifacts":{}}"#,
    )
    .unwrap();
    fs::write(d.join("client_init.bin"), [0u8; 8]).unwrap(); // 2 floats, need 4
    let m = Manifest::load(&d).unwrap();
    let err = m.load_client_init().unwrap_err();
    assert!(err.contains("expected"), "{err}");
}

// ---------------------------------------------------------------------
// adversarial payloads
// ---------------------------------------------------------------------

#[test]
fn payloads_with_hostile_headers_are_rejected() {
    use slacc::quant::payload::{ByteWriter, Header};
    // header claims enormous dims -> decompress must not try to allocate
    // the world before validating the body length
    let mut w = ByteWriter::new();
    Header { codec_id: slacc::codecs::ids::SLACC, dims: [60000, 60000, 60000, 4] }
        .write(&mut w);
    w.u16(1);
    let bytes = w.finish();
    let mut codec = codecs::by_name("slacc", 8, 10, 0).unwrap();
    // must return quickly with an error (truncated body), not OOM:
    // group parsing reads bits/channels before any big allocation
    assert!(codec.decode(&bytes).is_err());
}

#[test]
fn cross_codec_payloads_rejected_by_id() {
    let cm = Tensor::new(vec![1, 4, 2, 2], vec![0.5; 16]).to_channel_major();
    let mut a = codecs::by_name("uniform4", 4, 10, 0).unwrap();
    let wire = a.compress(&cm, RoundCtx::default());
    for other in ["slacc", "powerquant", "randtopk", "splitfc", "easyquant"] {
        let mut c = codecs::by_name(other, 4, 10, 0).unwrap();
        assert!(c.decode(&wire).is_err(), "{other} accepted a uniform payload");
    }
}

// ---------------------------------------------------------------------
// error-feedback extension
// ---------------------------------------------------------------------

#[test]
fn ef_wrapped_codecs_build_and_roundtrip() {
    let mut rng = Pcg32::seeded(1);
    let data: Vec<f32> = (0..2 * 8 * 4 * 4).map(|_| rng.next_gaussian()).collect();
    let cm = Tensor::new(vec![2, 8, 4, 4], data).to_channel_major();
    for base in ["slacc", "uniform4", "powerquant"] {
        let name = format!("ef:{base}");
        let mut c = codecs::by_name(&name, 8, 20, 2).unwrap();
        for _ in 0..5 {
            let wire = c.compress(&cm, RoundCtx::default());
            let rec = c.decode(&wire).unwrap();
            assert!(rec.data().iter().all(|v| v.is_finite()), "{name}");
        }
    }
    assert!(codecs::by_name("ef:bogus", 8, 20, 2).is_err());
}

#[test]
fn ef_config_validates() {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.codec = CodecChoice::Named("ef:slacc".into());
    cfg.validate().unwrap();
    cfg.codec = CodecChoice::Named("ef:nope".into());
    assert!(cfg.validate().is_err());
}

// ---------------------------------------------------------------------
// degenerate training configurations (engine-free parts)
// ---------------------------------------------------------------------

#[test]
fn partition_extreme_device_counts() {
    let d = synth_mnist::generate(64, 0);
    // more devices than samples per class
    let s = partition(&d, 50, Partition::Dirichlet { beta: 0.1 }, 1);
    s.validate(64).unwrap();
    for shard in &s.shards {
        assert!(!shard.is_empty());
    }
    // single sample dataset
    let tiny = synth_ham::generate(1, 2);
    let s = partition(&tiny, 1, Partition::Iid, 0);
    assert_eq!(s.shards[0], vec![0]);
}

#[test]
fn loader_survives_many_epochs() {
    let mut l = BatchLoader::new(&[1, 2, 3], 7, 0);
    for _ in 0..1000 {
        let b = l.next_batch();
        assert_eq!(b.len(), 7);
        assert!(b.iter().all(|&i| (1..=3).contains(&i)));
    }
    assert!(l.epoch() > 2000);
}

#[test]
fn network_sim_extreme_parameters() {
    // zero-byte transfers still pay latency; huge transfers don't overflow
    let link = DeviceLink { uplink_bps: 1e3, ..Default::default() };
    let sim = NetworkSim::homogeneous(2, link, ServerModel::default());
    let c = sim.round_cost(&[usize::MAX / 1024, 0], &[0, 0]);
    assert!(c.time_s.is_finite());
    assert!(c.time_s > 0.0);
}

#[test]
fn dataset_histogram_and_skew_bounds() {
    let d = synth_ham::generate(500, 3);
    let hist = d.class_histogram();
    assert_eq!(hist.iter().sum::<usize>(), 500);
    let s = partition(&d, 5, Partition::Dirichlet { beta: 0.5 }, 4);
    let skew = label_skew(&d, &s);
    assert!((0.0..=1.0).contains(&skew), "TV distance out of range: {skew}");
}

#[test]
fn config_rejects_pathologies() {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.lr = 0.0;
    assert!(cfg.validate().is_err());
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.lr = f32::NAN;
    assert!(cfg.validate().is_err());
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.eval_every = 0;
    assert!(cfg.validate().is_err());
}

#[test]
fn dataset_unknown_name_is_error() {
    assert!(Dataset::for_config("cifar", 8, 8, 0).is_err());
}
