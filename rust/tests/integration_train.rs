//! End-to-end trainer integration: short real training runs through the
//! full coordinator (PJRT + codecs + network sim + metrics).
//!
//! Requires `make artifacts`; tests skip gracefully otherwise.

use std::path::PathBuf;

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::codecs::selection::Selection;
use slacc::coordinator::trainer::Trainer;
use slacc::data::partition::Partition;

fn have_artifacts() -> bool {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/ham/manifest.json")
        .exists()
}

fn tiny_cfg(codec: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.artifacts_root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .to_string_lossy()
        .into_owned();
    cfg.rounds = 6;
    cfg.devices = 3;
    cfg.train_n = 128;
    cfg.test_n = 64;
    cfg.eval_every = 3;
    cfg.lr = 3e-3;
    cfg.codec = CodecChoice::Named(codec.into());
    cfg
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn slacc_short_run_trains() {
    require_artifacts!();
    let mut trainer = Trainer::new(tiny_cfg("slacc")).unwrap();
    let report = trainer.run().unwrap();
    assert_eq!(report.rounds_run, 6);
    assert_eq!(report.metrics.len(), 6);
    // losses finite, accuracy sane, bytes accounted
    for r in &report.metrics.records {
        assert!(r.loss.is_finite());
        assert!(r.bytes_up > 0);
        assert!(r.bytes_down > 0);
    }
    assert!(report.final_accuracy >= 0.0 && report.final_accuracy <= 1.0);
    assert!(report.total_sim_time_s > 0.0);
    // eval rounds: 3 and 6
    assert_eq!(report.metrics.accuracy_curve().len(), 2);
}

#[test]
fn compressed_run_uses_fewer_bytes_than_identity() {
    require_artifacts!();
    let r_id = Trainer::new(tiny_cfg("identity")).unwrap().run().unwrap();
    let r_sl = Trainer::new(tiny_cfg("slacc")).unwrap().run().unwrap();
    assert!(
        r_sl.total_bytes_up < r_id.total_bytes_up / 3,
        "slacc {} vs identity {}",
        r_sl.total_bytes_up,
        r_id.total_bytes_up
    );
    assert!(r_sl.total_sim_time_s < r_id.total_sim_time_s);
    // and compression must not explode the loss
    assert!(r_sl.metrics.mean_loss_tail(3) < r_id.metrics.mean_loss_tail(3) * 2.0 + 1.0);
}

#[test]
fn loss_decreases_over_short_horizon() {
    require_artifacts!();
    let mut cfg = tiny_cfg("slacc");
    cfg.rounds = 20;
    cfg.eval_every = 20;
    cfg.lr = 5e-3;
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    let first: f64 = report.metrics.records[..4].iter().map(|r| r.loss).sum::<f64>() / 4.0;
    let last = report.metrics.mean_loss_tail(4);
    assert!(
        last < first,
        "loss did not decrease: first4 {first:.4} -> last4 {last:.4}"
    );
}

#[test]
fn deterministic_given_seed() {
    require_artifacts!();
    let r1 = Trainer::new(tiny_cfg("slacc")).unwrap().run().unwrap();
    let r2 = Trainer::new(tiny_cfg("slacc")).unwrap().run().unwrap();
    assert_eq!(r1.metrics.records.len(), r2.metrics.records.len());
    for (a, b) in r1.metrics.records.iter().zip(&r2.metrics.records) {
        assert_eq!(a.loss, b.loss, "round {}", a.round);
        assert_eq!(a.bytes_up, b.bytes_up);
        assert_eq!(a.accuracy, b.accuracy);
    }
}

#[test]
fn noniid_partition_runs() {
    require_artifacts!();
    let mut cfg = tiny_cfg("slacc");
    cfg.partition = Partition::Dirichlet { beta: 0.5 };
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(report.rounds_run, 6);
    assert!(report.metrics.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn selection_codec_runs_end_to_end() {
    require_artifacts!();
    let mut cfg = tiny_cfg("identity");
    cfg.codec = CodecChoice::Select {
        strategy: Selection::EntropyBlended,
        n_select: 1,
    };
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    // single-channel payload: tiny uplink
    let full = 32 * 3 * 16 * 16 * 32 * 4; // C * (B*H*W) * devices... sanity only
    assert!(report.total_bytes_up < full);
    assert!(report.metrics.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn target_accuracy_early_stops() {
    require_artifacts!();
    let mut cfg = tiny_cfg("slacc");
    cfg.rounds = 50;
    cfg.eval_every = 1;
    cfg.target_accuracy = Some(0.05); // trivially reachable
    let report = Trainer::new(cfg).unwrap().run().unwrap();
    assert!(report.rounds_run < 50, "should early-stop");
    assert!(report.time_to_target_s.is_some());
}

#[test]
fn host_entropy_path_matches_kernel_path() {
    // entropy_via_kernel=false must produce numerically identical training
    // (the host mirror and the Pallas kernel agree to <1e-3, below any
    // grouping decision boundary at f32 scale on this data)
    require_artifacts!();
    let mut cfg_k = tiny_cfg("slacc");
    cfg_k.entropy_via_kernel = true;
    let mut cfg_h = tiny_cfg("slacc");
    cfg_h.entropy_via_kernel = false;
    let rk = Trainer::new(cfg_k).unwrap().run().unwrap();
    let rh = Trainer::new(cfg_h).unwrap().run().unwrap();
    for (a, b) in rk.metrics.records.iter().zip(&rh.metrics.records) {
        assert!((a.loss - b.loss).abs() < 0.05, "round {}: {} vs {}", a.round, a.loss, b.loss);
        assert_eq!(a.bytes_up, b.bytes_up, "round {}", a.round);
    }
}

#[test]
fn uncompressed_gradients_option() {
    require_artifacts!();
    let mut cfg = tiny_cfg("slacc");
    cfg.compress_gradients = false;
    let r = Trainer::new(cfg).unwrap().run().unwrap();
    // downlink rides an IdentityCodec envelope: payload header + raw f32
    // B*C*H*W tensor, per device per round — so the "communication
    // overhead" axis stays comparable with every compressed config
    use slacc::quant::payload::Header;
    let raw = (Header::BYTES + 32 * 32 * 16 * 16 * 4) * 3; // (hdr + batch*c*h*w*4) * devices
    assert_eq!(r.metrics.records[0].bytes_down, raw);
    assert!(r.metrics.records[0].bytes_up < raw / 3, "uplink still compressed");
}

/// The real engine through the real CLI transport pair: `slacc serve` +
/// 3 x `slacc device` over localhost TCP must reproduce the in-process
/// (loopback) trainer's per-round wire bytes exactly.
#[test]
fn tcp_engine_pair_matches_in_process_trainer() {
    require_artifacts!();
    use std::process::Command;

    let reference = Trainer::new(tiny_cfg("slacc")).unwrap().run().unwrap();

    let exe = env!("CARGO_BIN_EXE_slacc");
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let bind = format!("127.0.0.1:{port}");
    let csv = std::env::temp_dir()
        .join(format!("slacc_tcp_engine_{}.csv", std::process::id()));
    let cfg = tiny_cfg("slacc");
    let flags = |extra: &[&str]| -> Vec<String> {
        let mut v: Vec<String> = vec![
            "--dataset".into(), "ham".into(),
            "--artifacts".into(), cfg.artifacts_root.clone(),
            "--codec".into(), "slacc".into(),
            "--devices".into(), "3".into(),
            "--rounds".into(), "6".into(),
            "--train-n".into(), "128".into(),
            "--test-n".into(), "64".into(),
            "--eval-every".into(), "3".into(),
            "--lr".into(), "0.003".into(),
            "--seed".into(), "0".into(),
        ];
        v.extend(extra.iter().map(|s| s.to_string()));
        v
    };
    let mut server = Command::new(exe)
        .arg("serve")
        .args(flags(&["--bind", &bind, "--csv", &csv.to_string_lossy()]))
        .spawn()
        .unwrap();
    let devices: Vec<_> = (0..3)
        .map(|d| {
            Command::new(exe)
                .arg("device")
                .args(flags(&["--id", &d.to_string(), "--connect", &bind]))
                .spawn()
                .unwrap()
        })
        .collect();
    for (d, mut p) in devices.into_iter().enumerate() {
        assert!(p.wait().unwrap().success(), "device {d} failed");
    }
    assert!(server.wait().unwrap().success(), "server failed");

    let text = std::fs::read_to_string(&csv).unwrap();
    let _ = std::fs::remove_file(&csv);
    let lines: Vec<&str> = text.trim().lines().skip(1).collect();
    assert_eq!(lines.len(), reference.metrics.len());
    for (line, rec) in lines.iter().zip(&reference.metrics.records) {
        let f: Vec<&str> = line.split(',').collect();
        assert_eq!(f[3].parse::<usize>().unwrap(), rec.bytes_up, "round {}", rec.round);
        assert_eq!(f[4].parse::<usize>().unwrap(), rec.bytes_down, "round {}", rec.round);
    }
}

#[test]
fn delayed_client_aggregation() {
    require_artifacts!();
    let mut cfg = tiny_cfg("slacc");
    cfg.client_agg_every = 3;
    let r = Trainer::new(cfg).unwrap().run().unwrap();
    assert_eq!(r.rounds_run, 6);
    assert!(r.metrics.records.iter().all(|rec| rec.loss.is_finite()));
}

#[test]
fn ef_codec_trains_end_to_end() {
    require_artifacts!();
    let r = Trainer::new(tiny_cfg("ef:slacc")).unwrap().run().unwrap();
    assert!(r.metrics.records.iter().all(|rec| rec.loss.is_finite()));
    // EF does not change the wire format: bytes comparable to bare slacc
    let bare = Trainer::new(tiny_cfg("slacc")).unwrap().run().unwrap();
    let ef_up = r.metrics.records[0].bytes_up as f64;
    let bare_up = bare.metrics.records[0].bytes_up as f64;
    assert!((ef_up / bare_up - 1.0).abs() < 0.25, "{ef_up} vs {bare_up}");
}

#[test]
fn csv_export_works() {
    require_artifacts!();
    let report = Trainer::new(tiny_cfg("uniform4")).unwrap().run().unwrap();
    let path = std::env::temp_dir().join("slacc_test_metrics.csv");
    report.metrics.write_csv(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("round,loss"));
    assert_eq!(text.trim().lines().count(), 1 + report.metrics.len());
    let _ = std::fs::remove_file(&path);
}
