//! Adaptive-renegotiation integration: `--adapt` sessions end to end.
//!
//! Load-bearing properties:
//! * A forced `--adapt at:` schedule transitions the data-stream codecs
//!   mid-session with byte-for-byte parity between the in-process loopback
//!   path and a real multi-threaded TCP deployment — including the rounds
//!   on both sides of each activation boundary.
//! * The round CSV records the active spec table per round (new
//!   `active_spec` column; historical columns keep their indexes).
//! * A quorum close can carry a straggler *across* an activation
//!   boundary: its stale-round frames are served under the old table and
//!   the session stays deterministic.
//! * A SpecUpdate whose digest disagrees with its spec strings (or that
//!   tries to swap the session-long sync stream, or to activate an
//!   already-open round) is rejected by name at the device.
//! * An `--adapt` disagreement between server and device is a session
//!   fingerprint mismatch, rejected at the Hello handshake.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use slacc::codecs::stream::StreamSpecs;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::metrics::TrainReport;
use slacc::data::Dataset;
use slacc::sched::Policy;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::proto::Message;
use slacc::transport::server::{
    accept_and_serve, mock_runtime, run_mock_loopback, run_mock_loopback_delayed,
};
use slacc::transport::tcp::TcpTransport;

fn tiny_cfg(codec: &str, devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64;
    cfg.test_n = 16;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named(codec.into());
    cfg
}

fn run_tcp_session(cfg: &ExperimentConfig) -> TrainReport {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..cfg.devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(cfg, Arc::new(test)).unwrap();
    let report = accept_and_serve(&mut rt, &listener).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    report
}

/// Acceptance: a forced two-transition schedule (`slacc -> uniform8 ->
/// identity`) activates at the named rounds, changes the wire bytes, and
/// keeps loopback/TCP byte parity through both boundaries.
#[test]
fn forced_adapt_schedule_transitions_with_transport_parity() {
    let mut cfg = tiny_cfg("slacc", 3, 6);
    cfg.adapt = Some("at:2=uniform8,4=identity".into());
    let loopback = run_mock_loopback(&cfg).unwrap();
    assert_eq!(loopback.rounds_run, 6);

    // the per-round spec column walks the schedule exactly
    let specs: Vec<&str> =
        loopback.metrics.records.iter().map(|r| r.spec.as_str()).collect();
    assert_eq!(specs[0], "uplink=slacc downlink=slacc sync=identity");
    assert_eq!(specs[1], "uplink=slacc downlink=slacc sync=identity");
    assert_eq!(specs[2], "uplink=uniform8 downlink=uniform8 sync=identity");
    assert_eq!(specs[3], "uplink=uniform8 downlink=uniform8 sync=identity");
    assert_eq!(specs[4], "uplink=identity downlink=identity sync=identity");
    assert_eq!(specs[5], "uplink=identity downlink=identity sync=identity");

    // the transitions are real on the wire: the identity epoch ships raw
    // f32 activations, which dwarf both compressed epochs
    let by_round: Vec<usize> =
        loopback.metrics.records.iter().map(|r| r.bytes_up).collect();
    assert!(
        by_round[4] > 2 * by_round[3],
        "identity epoch should inflate uplink bytes: {by_round:?}"
    );

    let tcp = run_tcp_session(&cfg);
    assert_eq!(tcp.rounds_run, 6);
    assert_eq!(tcp.metrics.len(), loopback.metrics.len());
    for (l, t) in loopback.metrics.records.iter().zip(&tcp.metrics.records) {
        assert_eq!(l.bytes_up, t.bytes_up, "round {}", l.round);
        assert_eq!(l.bytes_down, t.bytes_down, "round {}", l.round);
        assert_eq!(l.bytes_sync, t.bytes_sync, "round {}", l.round);
        assert_eq!(l.loss, t.loss, "round {}", l.round);
        assert_eq!(l.accuracy, t.accuracy, "round {}", l.round);
        assert_eq!(l.spec, t.spec, "round {}", l.round);
    }
}

/// The adapted session is reproducible, and its pre-activation rounds are
/// byte-identical to the un-adapted session (the transition is the only
/// difference).
#[test]
fn adapted_session_is_deterministic_and_prefix_stable() {
    let mut cfg = tiny_cfg("slacc", 3, 5);
    cfg.adapt = Some("at:3=uniform4".into());
    let a = run_mock_loopback(&cfg).unwrap();
    let b = run_mock_loopback(&cfg).unwrap();
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.loss, y.loss, "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
        assert_eq!(x.spec, y.spec, "round {}", x.round);
    }
    let frozen = run_mock_loopback(&tiny_cfg("slacc", 3, 5)).unwrap();
    for r in 0..3 {
        assert_eq!(
            a.metrics.records[r].bytes_up, frozen.metrics.records[r].bytes_up,
            "pre-activation round {r} must match the frozen session"
        );
        assert_eq!(a.metrics.records[r].loss, frozen.metrics.records[r].loss);
    }
    assert_ne!(
        a.metrics.records[3].spec, frozen.metrics.records[3].spec,
        "the activation round must run the new table"
    );
}

/// The CSV gains `active_spec` as the LAST column; the historical columns
/// (bytes_up/bytes_down at indexes 3/4, which the distributed parity
/// checks parse) keep their positions.
#[test]
fn round_csv_records_the_active_spec_in_a_stable_layout() {
    let mut cfg = tiny_cfg("slacc", 2, 4);
    cfg.adapt = Some("at:2=uniform8".into());
    let report = run_mock_loopback(&cfg).unwrap();
    let csv = report.metrics.to_csv();
    let mut lines = csv.trim().lines();
    let header: Vec<&str> = lines.next().unwrap().split(',').collect();
    assert_eq!(header[3], "bytes_up");
    assert_eq!(header[4], "bytes_down");
    assert_eq!(*header.last().unwrap(), "active_spec");
    for (line, rec) in lines.zip(&report.metrics.records) {
        let f: Vec<&str> = line.split(',').collect();
        assert_eq!(f[3].parse::<usize>().unwrap(), rec.bytes_up, "round {}", rec.round);
        // the spec table contains no commas, so it stays one CSV field
        assert_eq!(*f.last().unwrap(), rec.spec, "round {}", rec.round);
    }
}

/// Acceptance: a quorum close carries the slow device across the
/// activation boundary — its stale-round frames are served under the old
/// table, the transition still lands, and the session is deterministic.
#[test]
fn straggler_carried_across_the_activation_boundary() {
    let mut cfg = tiny_cfg("slacc", 3, 8);
    cfg.eval_every = 20; // eval only at the end
    cfg.schedule = Policy::arrival_with_timeout(0.4, 2);
    cfg.adapt = Some("at:2=uniform4".into());
    // device 2 is far slower than the timeout window: round 0 closes on
    // the fast pair and carries it, so its round-0 work lands *after* the
    // uniform4 epoch activated
    let delays = [0.06, 0.06, 1.2];
    let (report, sched) = run_mock_loopback_delayed(&cfg, &delays, 7).unwrap();
    assert_eq!(report.rounds_run, 8);
    assert!(report.straggler_events >= 1, "no straggler was ever carried");
    assert!(
        sched.iter().any(|r| r.round >= 2 && r.stale.contains(&2)),
        "the straggler's stale work never landed past the boundary: {sched:?}"
    );
    assert_eq!(
        report.metrics.records[1].spec,
        "uplink=slacc downlink=slacc sync=identity"
    );
    assert_eq!(
        report.metrics.records[2].spec,
        "uplink=uniform4 downlink=uniform4 sync=identity"
    );
    // reproducible under the same shim seed
    let (again, sched2) = run_mock_loopback_delayed(&cfg, &delays, 7).unwrap();
    assert_eq!(sched, sched2);
    for (x, y) in report.metrics.records.iter().zip(&again.metrics.records) {
        assert_eq!(x.loss, y.loss, "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
    }
}

/// Hostile SpecUpdates are rejected at the device by name: a digest that
/// disagrees with the spec strings, a sync-stream swap, and an activation
/// round that is not in the future.
#[test]
fn device_rejects_malformed_spec_updates_by_name() {
    let cfg = tiny_cfg("slacc", 2, 4);
    let (train, _) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut worker = mock_worker(&cfg, Arc::new(train), 0).unwrap();

    let good = StreamSpecs::parse("uniform4", "uniform4", "identity").unwrap();

    // digest/spec-string disagreement
    let err = worker
        .handle(Message::SpecUpdate {
            activate_round: 2,
            uplink: "uniform4".into(),
            downlink: "uniform4".into(),
            sync: "identity".into(),
            streams_fp: good.fingerprint() ^ 1,
        })
        .unwrap_err();
    assert!(
        err.contains("digest") && err.contains("does not match"),
        "digest mismatch must be named: {err}"
    );

    // sync streams are session-long
    let synced = StreamSpecs::parse("uniform4", "uniform4", "uniform8").unwrap();
    let err = worker
        .handle(Message::SpecUpdate {
            activate_round: 2,
            uplink: "uniform4".into(),
            downlink: "uniform4".into(),
            sync: "uniform8".into(),
            streams_fp: synced.fingerprint(),
        })
        .unwrap_err();
    assert!(err.contains("sync"), "sync swap must be named: {err}");

    // an unparseable spec string never panics
    let err = worker
        .handle(Message::SpecUpdate {
            activate_round: 2,
            uplink: "bogus".into(),
            downlink: "uniform4".into(),
            sync: "identity".into(),
            streams_fp: 7,
        })
        .unwrap_err();
    assert!(err.contains("SpecUpdate"), "unexpected error: {err}");

    // a well-formed update is acked...
    let replies = worker
        .handle(Message::SpecUpdate {
            activate_round: 2,
            uplink: "uniform4".into(),
            downlink: "uniform4".into(),
            sync: "identity".into(),
            streams_fp: good.fingerprint(),
        })
        .unwrap();
    assert_eq!(
        replies,
        vec![Message::SpecUpdateAck {
            activate_round: 2,
            streams_fp: good.fingerprint()
        }]
    );

    // ...but a second one must queue strictly after it
    let err = worker
        .handle(Message::SpecUpdate {
            activate_round: 2,
            uplink: "uniform8".into(),
            downlink: "uniform8".into(),
            sync: "identity".into(),
            streams_fp: StreamSpecs::parse("uniform8", "uniform8", "identity")
                .unwrap()
                .fingerprint(),
        })
        .unwrap_err();
    assert!(err.contains("not after"), "unexpected error: {err}");
}

/// An `--adapt` disagreement between the endpoints changes the session
/// fingerprint and is rejected at the Hello handshake.
#[test]
fn adapt_disagreement_is_a_fingerprint_mismatch() {
    let mut server_cfg = tiny_cfg("slacc", 2, 4);
    server_cfg.adapt = Some("at:2=uniform4".into());
    let device_cfg = tiny_cfg("slacc", 2, 4); // no --adapt
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..2)
        .map(|d| {
            let cfg = device_cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || -> Result<(), String> {
                let (train, _) =
                    Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
                let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
                let mut conn =
                    TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
                run_blocking(&mut worker, &mut conn)
            })
        })
        .collect();
    let (_, test) = Dataset::for_config(
        &server_cfg.dataset,
        server_cfg.train_n,
        server_cfg.test_n,
        server_cfg.seed,
    )
    .unwrap();
    let mut rt = mock_runtime(&server_cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
    for h in handles {
        assert!(h.join().unwrap().is_err());
    }
}

/// Config validation: the directive is parsed up front, a ladder must
/// contain the session's starting uplink spec, and `--adapt` is
/// single-server only.
#[test]
fn adapt_directives_are_validated_up_front() {
    let mut cfg = tiny_cfg("slacc", 2, 4);
    cfg.adapt = Some("at:2=uniform4".into());
    cfg.validate().unwrap();

    cfg.adapt = Some("nonsense".into());
    assert!(cfg.validate().is_err());

    // the ladder must include the starting rung (uplink is slacc here)
    cfg.adapt = Some("ladder:uniform8,uniform4".into());
    assert!(cfg.validate().unwrap_err().contains("starting spec"));
    cfg.adapt = Some("ladder:slacc,uniform4".into());
    cfg.validate().unwrap();

    cfg.adapt = Some("at:2=uniform4".into());
    cfg.shards = 2;
    cfg.devices = 4;
    assert!(cfg.validate().unwrap_err().contains("single-server"));
}
