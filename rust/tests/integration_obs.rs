//! Telemetry-subsystem integration: the live scrape endpoint under a real
//! TCP training session, exact byte agreement between the metrics registry
//! and the end-of-run report, per-round snapshots, trace spans, and the
//! shard→coordinator counter roll-up — all engine-free via the mock compute.
//!
//! The metrics registry is process-global and cumulative, so every test
//! here serializes on one gate mutex and asserts on before/after *deltas*.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::Duration;

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::data::Dataset;
use slacc::obs::export::{MetricsExporter, SnapshotWriter};
use slacc::obs::{metrics, span, trace};
use slacc::shard::sim::run_sharded_mock;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{accept_and_serve_with, mock_runtime, run_mock_loopback};
use slacc::transport::tcp::TcpTransport;
use slacc::util::json::Json;

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    // a failed sibling test must not wedge the rest of the suite
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_cfg(devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64;
    cfg.test_n = 16;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg
}

/// Wire-byte counters (the accounted axis) as one snapshot.
fn wire_counters() -> (u64, u64, u64, u64) {
    (
        metrics::WIRE_UP_BYTES.get(),
        metrics::WIRE_DOWN_BYTES.get(),
        metrics::WIRE_SYNC_BYTES.get(),
        metrics::ROUNDS_CLOSED.get(),
    )
}

/// One blocking scrape of `addr`: full HTTP exchange, returns the body.
/// `None` when the endpoint is gone (session over) or stalls past 5s.
fn scrape(addr: &str) -> Option<String> {
    let mut conn = TcpStream::connect(addr).ok()?;
    conn.set_read_timeout(Some(Duration::from_secs(5))).ok()?;
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: slacc\r\n\r\n")
        .ok()?;
    let mut raw = String::new();
    conn.read_to_string(&mut raw).ok()?;
    let (head, body) = raw.split_once("\r\n\r\n")?;
    if !head.starts_with("HTTP/1.1 200 OK") {
        return None;
    }
    let len: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))?
        .parse()
        .ok()?;
    (body.len() == len).then(|| body.to_string())
}

/// Value of an exposition line whose full name (base + labels) is `name`.
fn exposition_value(body: &str, name: &str) -> Option<u64> {
    body.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse().ok()))
}

/// Exact agreement, loopback axis: the WIRE_* counter deltas across a
/// session equal the `TrainReport` byte totals *to the byte*, and rounds
/// closed equals rounds run.
#[test]
fn wire_counters_match_report_totals_exactly() {
    let _g = gate();
    let cfg = tiny_cfg(3, 4);
    let (up0, down0, sync0, rounds0) = wire_counters();
    let report = run_mock_loopback(&cfg).unwrap();
    let (up1, down1, sync1, rounds1) = wire_counters();
    assert_eq!(up1 - up0, report.total_bytes_up as u64);
    assert_eq!(down1 - down0, report.total_bytes_down as u64);
    assert_eq!(sync1 - sync0, report.total_bytes_sync as u64);
    assert_eq!(rounds1 - rounds0, report.rounds_run as u64);
    assert!(report.total_bytes_up > 0, "agreement on zero proves nothing");
}

/// The acceptance bar: a real TCP session with `--metrics-bind` serves
/// Prometheus text *mid-run* from the event loop; scraped counters are
/// monotonic, the accounted byte axis lands exactly on the report totals
/// (which themselves match loopback byte-for-byte), and the per-round
/// snapshot writer emits one parseable JSONL row per round.
#[test]
fn live_scrape_during_tcp_session_agrees_with_report() {
    let _g = gate();
    let cfg = tiny_cfg(4, 24);
    let loopback = run_mock_loopback(&cfg).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let exporter = MetricsExporter::bind("127.0.0.1:0").unwrap();
    let scrape_addr = exporter.local_addr().to_string();
    let snap_path = std::env::temp_dir().join(format!(
        "slacc_obs_snapshots_{}.jsonl",
        std::process::id()
    ));
    let snap_path = snap_path.to_str().unwrap().to_string();

    // scraper runs concurrently with the session: connections queue in the
    // listener backlog and are serviced from the event loop's poll_step, so
    // the first scrapes complete while rounds are still closing; once the
    // session ends the exporter is gone and the scraper stops
    let scraper = thread::spawn({
        let scrape_addr = scrape_addr.clone();
        move || {
            let mut samples: Vec<(u64, u64, u64)> = Vec::new();
            for _ in 0..512 {
                let Some(body) = scrape(&scrape_addr) else { break };
                samples.push((
                    exposition_value(&body, "slacc_frames_recv_total").unwrap(),
                    exposition_value(&body, "slacc_rounds_closed_total").unwrap(),
                    exposition_value(&body, "slacc_wire_bytes_total{stream=\"uplink\"}")
                        .unwrap(),
                ));
            }
            samples
        }
    });

    let (up0, down0, sync0, _) = wire_counters();
    let scrapes0 = metrics::SCRAPES.get();
    let mut handles = Vec::new();
    for d in 0..cfg.devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    rt.attach_snapshot_writer(SnapshotWriter::create(&snap_path, 1).unwrap());
    let report = accept_and_serve_with(&mut rt, &listener, Some(exporter)).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    let samples = scraper.join().unwrap();
    let (up1, down1, sync1, _) = wire_counters();

    // exact byte agreement on the accounted axis, TCP side
    assert_eq!(up1 - up0, report.total_bytes_up as u64);
    assert_eq!(down1 - down0, report.total_bytes_down as u64);
    assert_eq!(sync1 - sync0, report.total_bytes_sync as u64);
    // and the TCP totals are the loopback totals (transport parity)
    assert_eq!(report.total_bytes_up, loopback.total_bytes_up);
    assert_eq!(report.total_bytes_down, loopback.total_bytes_down);

    // the endpoint really served mid-run: several scrapes landed, every
    // sampled counter is monotonic, and the final samples are bounded by
    // the end-of-process registry state
    assert!(
        samples.len() >= 2,
        "only {} scrape(s) completed during a 24-round session",
        samples.len()
    );
    assert!(metrics::SCRAPES.get() - scrapes0 >= samples.len() as u64);
    for pair in samples.windows(2) {
        assert!(pair[0].0 <= pair[1].0, "frames_recv went backwards");
        assert!(pair[0].1 <= pair[1].1, "rounds_closed went backwards");
        assert!(pair[0].2 <= pair[1].2, "wire uplink bytes went backwards");
    }
    let last = samples.last().unwrap();
    assert!(last.0 <= metrics::FRAMES_RECV.get());
    assert!(last.2 <= up1);

    // snapshot writer: one row per closed round, every row parses, the
    // uplink byte counter is monotonic across rows and ends on the total
    let text = std::fs::read_to_string(&snap_path).unwrap();
    let _ = std::fs::remove_file(&snap_path);
    let rows: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(rows.len(), report.rounds_run);
    let up_name = "slacc_wire_bytes_total{stream=\"uplink\"}";
    let mut prev = up0 as f64;
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row.at(&["round"]), &Json::Num(i as f64));
        match row.at(&["metrics", "counters", up_name]) {
            Json::Num(v) => {
                assert!(*v >= prev, "snapshot {i}: uplink bytes went backwards");
                prev = *v;
            }
            other => panic!("snapshot {i}: {up_name} missing, got {other:?}"),
        }
    }
    assert_eq!(prev, up1 as f64, "last snapshot must carry the final total");
}

/// Trace spans recorded through a real session drain to parseable JSONL
/// with the server-compute span present; disabling the gate afterwards
/// stops recording.
#[test]
fn session_spans_drain_to_jsonl() {
    let _g = gate();
    let _ = span::drain(); // discard anything a prior test recorded
    span::set_enabled(true);
    let report = run_mock_loopback(&tiny_cfg(3, 3));
    span::set_enabled(false);
    report.unwrap();
    let path = std::env::temp_dir().join(format!(
        "slacc_obs_spans_{}.jsonl",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    let n = span::write_jsonl(&path).unwrap();
    assert!(n > 0, "an instrumented session must record spans");
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    // line 0 is the joinability header (role / shard / session / anchors)
    let mut lines = text.lines();
    let header = Json::parse(lines.next().unwrap()).unwrap();
    assert_eq!(header.at(&["header"]), &Json::Num(1.0));
    assert!(header.get("role").is_some());
    assert!(header.get("anchors").is_some());
    let mut saw_batch = false;
    for line in lines {
        let row = Json::parse(line).unwrap();
        let Some(name) = row.get("name") else {
            continue; // a per-thread dropped-count row
        };
        if name == &Json::Str("server_step_batch".to_string()) {
            saw_batch = true;
            match row.at(&["dur_ns"]) {
                Json::Num(v) => assert!(*v >= 0.0),
                other => panic!("dur_ns must be numeric, got {other:?}"),
            }
        }
    }
    assert!(saw_batch, "server_step_batch span missing from the trace");

    // gate closed again: a fresh session records nothing
    run_mock_loopback(&tiny_cfg(2, 2)).unwrap();
    assert!(
        span::drain().is_empty(),
        "spans recorded while the gate was disabled"
    );
}

/// Tentpole acceptance, in-process edition: a sharded multi-thread mock
/// session drains a trace the analyzer can fully join — every round
/// reconstructed with a critical device and a stage chain that covers at
/// least the round wall clock, zero unjoined lifecycle spans, zero ring
/// drops.
#[test]
fn sharded_session_traces_are_fully_joinable() {
    let _g = gate();
    let _ = span::drain(); // discard anything a prior test recorded
    span::set_enabled(true);
    span::set_trace_role("server", 0);
    let mut cfg = tiny_cfg(4, 4);
    cfg.train_n = 128;
    cfg.test_n = 32;
    cfg.shards = 2;
    cfg.shard_sync_every = 1;
    let result = run_sharded_mock(&cfg);
    span::set_enabled(false);
    result.unwrap();

    let path = std::env::temp_dir().join(format!(
        "slacc_obs_joinable_{}.jsonl",
        std::process::id()
    ));
    let path = path.to_str().unwrap().to_string();
    let n = span::write_jsonl(&path).unwrap();
    assert!(n > 0, "an instrumented sharded session must record spans");
    let node = trace::parse_file(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(node.role, "server");

    let analysis = trace::analyze(vec![node]).unwrap();
    assert_eq!(analysis.unjoined, 0, "every lifecycle span must join a round");
    assert_eq!(analysis.dropped, 0, "tiny session must not overwrite its rings");
    let got: Vec<u32> = analysis.rounds.iter().map(|r| r.round).collect();
    let want: Vec<u32> = (0..cfg.rounds as u32).collect();
    assert_eq!(got, want, "every round must be reconstructable");
    for r in &analysis.rounds {
        assert!(r.wall_ns > 0, "round {} has no wall clock", r.round);
        assert!(r.participants > 0, "round {} joined no devices", r.round);
        assert!(
            r.critical_gid.is_some(),
            "round {} has no critical device",
            r.round
        );
        // `other` absorbs any un-instrumented remainder, so the chain can
        // never undershoot the wall clock (overlapping shard stages in this
        // single-process sim can make it exceed it)
        let sum: i64 = r.stages.iter().map(|s| s.1).sum();
        assert!(
            sum >= r.wall_ns,
            "round {}: stage chain {}ns under the {}ns wall",
            r.round,
            sum,
            r.wall_ns
        );
    }
    assert!(trace::summary(&analysis).contains("dropped spans: 0"));
}

/// The committed two-node fixture reproduces its golden critical-path
/// table: clock alignment via the handshake anchors, derived wire stages,
/// and an exact stages-sum-to-wall decomposition per round.
#[test]
fn fixture_traces_reproduce_the_golden_table() {
    let nodes = vec![
        trace::parse_trace(
            "server.jsonl",
            include_str!("fixtures/trace/server.jsonl"),
        )
        .unwrap(),
        trace::parse_trace(
            "device0.jsonl",
            include_str!("fixtures/trace/device0.jsonl"),
        )
        .unwrap(),
        trace::parse_trace(
            "device1.jsonl",
            include_str!("fixtures/trace/device1.jsonl"),
        )
        .unwrap(),
    ];
    let a = trace::analyze(nodes).unwrap();
    assert_eq!(a.session_fp, "00000000deadbeef");
    assert_eq!(a.unjoined, 0);
    assert_eq!(a.dropped, 0);
    assert_eq!(a.rounds.len(), 2);
    // the fixture is overlap-free, so the decomposition is exact
    for r in &a.rounds {
        let sum: i64 = r.stages.iter().map(|s| s.1).sum();
        assert_eq!(sum, r.wall_ns, "round {} chain must sum to its wall", r.round);
        assert_eq!(r.participants, 2);
    }
    assert_eq!(a.rounds[0].critical_gid, Some(1));
    assert_eq!(a.rounds[1].critical_gid, Some(0));
    assert_eq!(a.rounds[0].bounding_stage, "client_fwd");
    assert_eq!(a.rounds[0].bounding_ns, 2_000_000);
    assert_eq!(a.straggler_counts, vec![(0, 1), (1, 1)]);

    // golden table comparison, whitespace-normalized so only the numbers
    // and their order are load-bearing
    fn norm(s: &str) -> String {
        s.lines()
            .map(|l| l.split_whitespace().collect::<Vec<_>>().join(" "))
            .filter(|l| !l.is_empty())
            .collect::<Vec<_>>()
            .join("\n")
    }
    let table = trace::render_table(&a);
    let golden = include_str!("fixtures/trace/expected_table.txt");
    assert_eq!(
        norm(&table),
        norm(golden),
        "critical-path table drifted from the golden fixture; got:\n{table}"
    );

    // the Chrome export carries one complete event per span, clock-aligned
    let chrome = trace::chrome_json(&a);
    let arr = chrome.as_arr().unwrap();
    assert_eq!(arr.len(), a.events.len());
    let fwd = arr
        .iter()
        .find(|e| {
            e.at(&["name"]) == &Json::Str("client_fwd".into())
                && e.at(&["args", "round"]) == &Json::Num(0.0)
                && e.at(&["tid"]) == &Json::Num(1.0)
        })
        .expect("device 1's round-0 client_fwd missing from the Chrome export");
    // device-1 local 8_700_000ns + the 1_500_000ns anchor offset, in us
    assert_eq!(fwd.at(&["ts"]), &Json::Num(10_200.0));
}

/// The counter roll-up piggybacked on ShardSync reaches the coordinator
/// through the real coordinator tier: cluster totals resolve to registry
/// names and cover the whole cluster's closed rounds. (In this in-process
/// sim both shard threads share one process registry, so summed values are
/// upper bounds, not per-shard figures — the assertion is plumbing, names,
/// and lower bounds.)
#[test]
fn shard_rollup_reaches_coordinator_cluster_totals() {
    let _g = gate();
    let mut cfg = tiny_cfg(4, 4);
    cfg.train_n = 128;
    cfg.test_n = 32;
    cfg.shards = 2;
    cfg.shard_sync_every = 1;
    let sharded = run_sharded_mock(&cfg).unwrap();
    let totals = &sharded.coordinator.cluster_counters;
    assert!(!totals.is_empty(), "coordinator collected no roll-ups");
    for (name, _) in totals {
        assert!(
            name.starts_with("slacc_"),
            "unresolved roll-up counter: {name}"
        );
    }
    let rounds = sharded
        .coordinator
        .cluster_counter("slacc_rounds_closed_total")
        .expect("rounds_closed missing from cluster totals");
    let run: usize = sharded.shard_reports.iter().map(|r| r.rounds_run).sum();
    assert!(
        rounds >= run as u64,
        "cluster rounds_closed {rounds} below the {run} rounds the shards ran"
    );
    assert!(sharded
        .coordinator
        .cluster_counter("slacc_shard_syncs_total")
        .is_some());
}
