//! Transport-subsystem integration: framed protocol sessions end-to-end
//! over loopback and real TCP sockets, engine-free via the deterministic
//! mock compute (plus an artifact-gated run through the real CLI pair).
//!
//! The load-bearing property: for one config and seed, the per-round
//! smashed-data byte counts are *identical* across the in-process loopback
//! path and a concurrent multi-process/multi-thread TCP deployment.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::metrics::TrainReport;
use slacc::data::Dataset;
use slacc::quant::payload::Header;
use slacc::transport::compute::{MOCK_BATCH, MOCK_CUT};
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{accept_and_serve, mock_runtime, run_mock_loopback};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::Transport;

fn tiny_cfg(codec: &str, devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64;
    cfg.test_n = 16;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named(codec.into());
    cfg
}

fn run_tcp_session(cfg: &ExperimentConfig) -> TrainReport {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..cfg.devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(cfg, Arc::new(test)).unwrap();
    let report = accept_and_serve(&mut rt, &listener).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    report
}

#[test]
fn mock_loopback_session_trains_and_accounts_bytes() {
    let cfg = tiny_cfg("slacc", 3, 4);
    let report = run_mock_loopback(&cfg).unwrap();
    assert_eq!(report.rounds_run, 4);
    assert_eq!(report.metrics.len(), 4);
    for r in &report.metrics.records {
        assert!(r.loss.is_finite());
        assert!(r.bytes_up > 0);
        assert!(r.bytes_down > 0);
    }
    // eval rounds: 2 and 4
    assert_eq!(report.metrics.accuracy_curve().len(), 2);
    assert!(report.total_sim_time_s > 0.0);
}

#[test]
fn mock_loopback_is_deterministic() {
    let cfg = tiny_cfg("slacc", 3, 3);
    let a = run_mock_loopback(&cfg).unwrap();
    let b = run_mock_loopback(&cfg).unwrap();
    assert_eq!(a.metrics.len(), b.metrics.len());
    for (x, y) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(x.loss, y.loss, "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
        assert_eq!(x.bytes_down, y.bytes_down, "round {}", x.round);
        assert_eq!(x.accuracy, y.accuracy, "round {}", x.round);
    }
}

#[test]
fn every_codec_survives_a_loopback_session() {
    for codec in ["identity", "uniform4", "slacc", "powerquant", "randtopk", "splitfc"] {
        let cfg = tiny_cfg(codec, 2, 2);
        let report = run_mock_loopback(&cfg)
            .unwrap_or_else(|e| panic!("codec {codec}: {e}"));
        assert_eq!(report.rounds_run, 2, "codec {codec}");
        assert!(report.metrics.records.iter().all(|r| r.loss.is_finite()));
    }
}

#[test]
fn uncompressed_downlink_pays_the_envelope_header() {
    let mut cfg = tiny_cfg("slacc", 3, 2);
    cfg.compress_gradients = false;
    let report = run_mock_loopback(&cfg).unwrap();
    // identity envelope per device: payload header + raw f32 cut tensor
    let (c, h, w) = MOCK_CUT;
    let per_device = Header::BYTES + MOCK_BATCH * c * h * w * 4;
    assert_eq!(report.metrics.records[0].bytes_down, 3 * per_device);
    // uplink stays compressed
    assert!(report.metrics.records[0].bytes_up < 3 * per_device);
}

#[test]
fn tcp_session_matches_loopback_byte_for_byte() {
    let cfg = tiny_cfg("slacc", 4, 3);
    let loopback = run_mock_loopback(&cfg).unwrap();
    let tcp = run_tcp_session(&cfg);
    assert_eq!(tcp.rounds_run, 3);
    assert_eq!(tcp.metrics.len(), loopback.metrics.len());
    for (l, t) in loopback.metrics.records.iter().zip(&tcp.metrics.records) {
        assert_eq!(l.bytes_up, t.bytes_up, "round {}", l.round);
        assert_eq!(l.bytes_down, t.bytes_down, "round {}", l.round);
        assert_eq!(l.loss, t.loss, "round {}", l.round);
        assert_eq!(l.accuracy, t.accuracy, "round {}", l.round);
    }
}

#[test]
fn tcp_session_matches_loopback_with_identity_codec() {
    let mut cfg = tiny_cfg("identity", 2, 3);
    cfg.compress_gradients = false;
    let loopback = run_mock_loopback(&cfg).unwrap();
    let tcp = run_tcp_session(&cfg);
    for (l, t) in loopback.metrics.records.iter().zip(&tcp.metrics.records) {
        assert_eq!((l.bytes_up, l.bytes_down), (t.bytes_up, t.bytes_down));
    }
}

/// Acceptance: a mixed-stream session (`--uplink-codec slacc
/// --downlink-codec uniform8 --sync-codec uniform8`) trains end-to-end
/// over loopback AND TCP with byte-for-byte parity between the two
/// transports.
#[test]
fn mixed_stream_session_matches_across_transports() {
    let mut cfg = tiny_cfg("slacc", 3, 3);
    cfg.uplink_codec = Some("slacc".into());
    cfg.downlink_codec = Some("uniform8".into());
    cfg.sync_codec = Some("uniform8".into());
    let loopback = run_mock_loopback(&cfg).unwrap();
    let tcp = run_tcp_session(&cfg);
    assert_eq!(tcp.rounds_run, 3);
    assert_eq!(tcp.metrics.len(), loopback.metrics.len());
    for (l, t) in loopback.metrics.records.iter().zip(&tcp.metrics.records) {
        assert_eq!(l.bytes_up, t.bytes_up, "round {}", l.round);
        assert_eq!(l.bytes_down, t.bytes_down, "round {}", l.round);
        assert_eq!(l.bytes_sync, t.bytes_sync, "round {}", l.round);
        assert_eq!(l.loss, t.loss, "round {}", l.round);
        assert_eq!(l.accuracy, t.accuracy, "round {}", l.round);
    }
    // the mixed table genuinely differs from the all-slacc shorthand run
    let all_slacc = run_mock_loopback(&tiny_cfg("slacc", 3, 3)).unwrap();
    assert_eq!(loopback.total_bytes_up, all_slacc.total_bytes_up);
    assert_ne!(loopback.total_bytes_down, all_slacc.total_bytes_down);
    assert_ne!(loopback.total_bytes_sync, all_slacc.total_bytes_sync);
}

/// Per-stream byte accounting: the report carries a compression ratio per
/// StreamKind, and each behaves as its codec implies (slacc uplink
/// compresses well; an identity sync stream sits at ~1x after envelope
/// overhead).
#[test]
fn per_stream_ratios_are_reported() {
    let cfg = tiny_cfg("slacc", 3, 4);
    let report = run_mock_loopback(&cfg).unwrap();
    assert!(
        report.ratio_up > 2.0,
        "slacc uplink ratio {} too low",
        report.ratio_up
    );
    assert!(
        report.ratio_down > 2.0,
        "slacc downlink ratio {} too low",
        report.ratio_down
    );
    // identity sync: raw/wire slightly below 1 (envelope + shape table)
    assert!(
        report.ratio_sync > 0.5 && report.ratio_sync <= 1.0,
        "identity sync ratio {} out of range",
        report.ratio_sync
    );
    for rec in &report.metrics.records {
        assert!(rec.raw_up > rec.bytes_up, "round {}", rec.round);
        assert_eq!(rec.ratio_up(), rec.raw_up as f64 / rec.bytes_up as f64);
    }
}

/// Acceptance: a per-stream spec disagreement is rejected at the Hello
/// handshake with an error naming the offending stream.
#[test]
fn per_stream_spec_mismatch_rejected_at_hello() {
    let mut server_cfg = tiny_cfg("slacc", 2, 3);
    server_cfg.downlink_codec = Some("uniform8".into());
    let device_cfg = tiny_cfg("slacc", 2, 3); // downlink = slacc shorthand
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..2)
        .map(|d| {
            let cfg = device_cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || -> Result<(), String> {
                let (train, _) =
                    Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
                let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
                let mut conn =
                    TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
                run_blocking(&mut worker, &mut conn)
            })
        })
        .collect();
    let (_, test) = Dataset::for_config(
        &server_cfg.dataset,
        server_cfg.train_n,
        server_cfg.test_n,
        server_cfg.seed,
    )
    .unwrap();
    let mut rt = mock_runtime(&server_cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    assert!(
        err.contains("downlink") && err.contains("--downlink-codec"),
        "error must name the mismatched stream: {err}"
    );
    for h in handles {
        assert!(h.join().unwrap().is_err());
    }
}

#[test]
fn config_mismatch_is_rejected_at_handshake() {
    // same fleet size and codec, but the device runs a different lr —
    // the session fingerprint must catch it before any training happens
    let server_cfg = tiny_cfg("slacc", 2, 3);
    let mut device_cfg = tiny_cfg("slacc", 2, 3);
    device_cfg.lr = 0.1;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..2)
        .map(|d| {
            let cfg = device_cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || -> Result<(), String> {
                let (train, _) =
                    Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
                let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
                let mut conn =
                    TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
                run_blocking(&mut worker, &mut conn)
            })
        })
        .collect();
    let (_, test) = Dataset::for_config(
        &server_cfg.dataset,
        server_cfg.train_n,
        server_cfg.test_n,
        server_cfg.seed,
    )
    .unwrap();
    let mut rt = mock_runtime(&server_cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    assert!(err.contains("fingerprint"), "unexpected error: {err}");
    for h in handles {
        assert!(h.join().unwrap().is_err());
    }
}

#[test]
fn device_count_mismatch_is_rejected() {
    // server expects 2 devices; the lone worker claims a 3-device fleet
    let server_cfg = tiny_cfg("slacc", 2, 2);
    let device_cfg = tiny_cfg("slacc", 3, 2);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handles: Vec<_> = (0..2)
        .map(|d| {
            let cfg = device_cfg.clone();
            let addr = addr.clone();
            thread::spawn(move || -> Result<(), String> {
                let (train, _) =
                    Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
                let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
                let mut conn =
                    TcpTransport::connect_retry(&addr, 40, Duration::from_millis(100))?;
                run_blocking(&mut worker, &mut conn)
            })
        })
        .collect();
    let (_, test) = Dataset::for_config(
        &server_cfg.dataset,
        server_cfg.train_n,
        server_cfg.test_n,
        server_cfg.seed,
    )
    .unwrap();
    let mut rt = mock_runtime(&server_cfg, Arc::new(test)).unwrap();
    let err = accept_and_serve(&mut rt, &listener).unwrap_err();
    assert!(err.contains("devices"), "unexpected error: {err}");
    // workers end with an error (connection dropped), not a hang
    for h in handles {
        assert!(h.join().unwrap().is_err());
    }
}

/// End-to-end through the real CLI binaries: `slacc serve --mock` + N x
/// `slacc device --mock` over localhost TCP, then parity against the
/// in-process loopback run. Exercises main.rs, the handshake, and the CSV
/// export with zero artifacts.
#[test]
fn cli_serve_device_pair_matches_loopback() {
    use std::process::Command;

    let exe = env!("CARGO_BIN_EXE_slacc");
    // reserve a port, then free it for the server (minor race, fine in CI)
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let bind = format!("127.0.0.1:{port}");
    let csv = std::env::temp_dir()
        .join(format!("slacc_cli_transport_{}.csv", std::process::id()));
    let flags = |extra: &[&str]| -> Vec<String> {
        let mut v: Vec<String> = [
            "--mock", "--dataset", "ham", "--codec", "slacc", "--devices", "2",
            "--rounds", "3", "--train-n", "64", "--test-n", "16", "--eval-every",
            "2", "--seed", "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        v.extend(extra.iter().map(|s| s.to_string()));
        v
    };

    let mut server = Command::new(exe)
        .arg("serve")
        .args(flags(&["--bind", &bind, "--csv", &csv.to_string_lossy()]))
        .spawn()
        .unwrap();
    let devices: Vec<_> = (0..2)
        .map(|d| {
            Command::new(exe)
                .arg("device")
                .args(flags(&["--id", &d.to_string(), "--connect", &bind]))
                .spawn()
                .unwrap()
        })
        .collect();
    for (d, mut p) in devices.into_iter().enumerate() {
        let st = p.wait().unwrap();
        assert!(st.success(), "device {d} exited with {st}");
    }
    let st = server.wait().unwrap();
    assert!(st.success(), "server exited with {st}");

    let text = std::fs::read_to_string(&csv).unwrap();
    let _ = std::fs::remove_file(&csv);
    let reference = run_mock_loopback(&tiny_cfg("slacc", 2, 3)).unwrap();
    let lines: Vec<&str> = text.trim().lines().skip(1).collect();
    assert_eq!(lines.len(), reference.metrics.len());
    for (line, rec) in lines.iter().zip(&reference.metrics.records) {
        let f: Vec<&str> = line.split(',').collect();
        assert_eq!(f[3].parse::<usize>().unwrap(), rec.bytes_up, "round {}", rec.round);
        assert_eq!(f[4].parse::<usize>().unwrap(), rec.bytes_down, "round {}", rec.round);
        let loss: f64 = f[1].parse().unwrap();
        assert!((loss - rec.loss).abs() < 1e-5, "round {}", rec.round);
    }
}

/// Wire-stats sanity on a raw transport pair: framed bytes exceed payload
/// bytes (the protocol overhead is observable, not hidden).
#[test]
fn wire_stats_track_framing_overhead() {
    use slacc::transport::proto::Message;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let payload = vec![7u8; 1000];
    let sent = payload.clone();
    let client = thread::spawn(move || {
        let mut t = TcpTransport::connect(&addr).unwrap();
        t.send(&Message::Activations {
            round: 0,
            device_id: 0,
            labels: vec![1, 2, 3],
            payload: sent,
        })
        .unwrap();
        t.stats().bytes_sent
    });
    let mut server = TcpTransport::accept(&listener).unwrap();
    let msg = server.recv().unwrap();
    let bytes_sent = client.join().unwrap();
    match msg {
        Message::Activations { payload: p, .. } => assert_eq!(p, payload),
        other => panic!("unexpected {}", other.type_name()),
    }
    assert!(bytes_sent > 1000, "framed bytes {bytes_sent} must exceed payload");
    assert_eq!(server.stats().bytes_recv, bytes_sent);
}
