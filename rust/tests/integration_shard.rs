//! Topology-tier integration: the multi-server sharding stack end to end.
//!
//! Load-bearing properties:
//! * A 2-shard × 2-device cluster trains end to end through the
//!   coordinator tier and, at `--shard-sync-every 1`, lands within noise
//!   of the equivalent 4-device single-server session (the mock model
//!   makes the eval exactly reproducible, so "within noise" is pinned
//!   tightly).
//! * Topology-mismatched ShardHellos — wrong shard count, wrong sync
//!   cadence, a device pointed at a coordinator port — are rejected at
//!   handshake, naming the offending flag.
//! * A shard that vanishes mid-session surfaces as a typed peer-closed
//!   error on the coordinator, never a hang.
//! * `--shard-sync-every K` amortization is visible on the `bytes_sync`
//!   axis: shard-link traffic lands only on sync rounds, and a larger K
//!   moves fewer sync bytes in total.
//! * The TCP cluster is byte-for-byte identical to the in-process
//!   channel-transport simulation (the shard-tier twin of the PR 1
//!   loopback/TCP parity goldens).

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::metrics::TrainReport;
use slacc::data::Dataset;
use slacc::sched::fleet::ShardFleet;
use slacc::shard::coordinator::{CoordReport, Coordinator};
use slacc::shard::link::ShardLink;
use slacc::shard::checkpoint::Checkpoint;
use slacc::shard::sim::{run_sharded_mock, run_sharded_mock_resumed};
use slacc::shard::{FleetShape, Topology};
use slacc::transport::channel;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::proto::Message;
use slacc::transport::server::{
    accept_and_serve, handshake, mock_runtime_for_shard, run_mock_loopback,
};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::{loopback, session_fingerprint, Transport};

fn sharded_cfg(
    devices: usize,
    shards: usize,
    rounds: usize,
    sync_every: usize,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 128;
    cfg.test_n = 32;
    cfg.eval_every = 2;
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg.shards = shards;
    cfg.shard_sync_every = sync_every;
    cfg
}

/// The acceptance bar: a 2-shard × 2-device cluster through the full
/// coordinator tier reaches the same accuracy as the 4-device
/// single-server session at sync-every-1, and every shard evaluates the
/// *same* merged models.
#[test]
fn two_shard_cluster_matches_single_server_within_noise() {
    let single = run_mock_loopback(&sharded_cfg(4, 1, 6, 1)).unwrap();
    let cfg = sharded_cfg(4, 2, 6, 1);
    let sharded = run_sharded_mock(&cfg).unwrap();

    assert_eq!(sharded.shard_reports.len(), 2);
    for (k, rep) in sharded.shard_reports.iter().enumerate() {
        assert_eq!(rep.rounds_run, 6, "shard {k}");
        assert!(
            rep.metrics.records.iter().all(|r| r.loss.is_finite()),
            "shard {k}: non-finite loss"
        );
        assert!(rep.total_bytes_up > 0 && rep.total_bytes_down > 0, "shard {k}");
    }
    // at sync-every-1 every eval happens after a cross-shard merge, so
    // both shards score the identical cluster model
    let (lo, hi) = sharded.accuracy_range();
    assert_eq!(lo, hi, "shards evaluated different models after a full merge");
    assert!(
        (hi - single.final_accuracy).abs() < 0.05,
        "sharded accuracy {hi} far from single-server {}",
        single.final_accuracy
    );
    // the coordinator merged every round and moved real bytes
    assert_eq!(sharded.coordinator.sync_epochs, 6);
    assert!(sharded.coordinator.bytes_up > 0);
    assert!(sharded.coordinator.bytes_down > 0);
    for (k, &(up, down)) in sharded.coordinator.per_shard.iter().enumerate() {
        assert!(up > 0 && down > 0, "shard {k} moved no sync-tier bytes");
    }
}

/// `shards == 1` through the sharded entry point is exactly the
/// single-server loopback session (no coordinator, no shard link).
#[test]
fn one_shard_degenerates_to_the_single_server_session() {
    let cfg = sharded_cfg(3, 1, 3, 1);
    let single = run_mock_loopback(&cfg).unwrap();
    let sharded = run_sharded_mock(&cfg).unwrap();
    assert_eq!(sharded.shard_reports.len(), 1);
    assert_eq!(sharded.coordinator.sync_epochs, 0);
    let (a, b) = (&single.metrics.records, &sharded.shard_reports[0].metrics.records);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "round {}", x.round);
        assert_eq!(x.bytes_up, y.bytes_up, "round {}", x.round);
        assert_eq!(x.bytes_sync, y.bytes_sync, "round {}", x.round);
    }
}

#[test]
fn coordinator_rejects_wrong_shard_count_at_handshake() {
    let cfg = sharded_cfg(4, 2, 2, 1);
    let (shard_end0, coord_end0) = channel::pair("fake0");
    let (_keep_alive, coord_end1) = channel::pair("fake1");
    let fake = thread::spawn(move || {
        let mut conn = shard_end0;
        let hello = conn.recv().unwrap();
        assert!(matches!(hello, Message::ShardHello { .. }));
        // echo back a 3-shard topology against the coordinator's 2
        conn.send(&Message::ShardHello {
            shard_id: 0,
            shards: 3,
            sync_every: 1,
            config_fp: 0,
            weight: 64,
        })
        .unwrap();
    });
    let mut coordinator = Coordinator::from_experiment(&cfg, "mock").unwrap();
    let mut fleet =
        ShardFleet::new(vec![Box::new(coord_end0), Box::new(coord_end1)]);
    let err = coordinator.run(&mut fleet).unwrap_err();
    assert!(err.contains("--shards"), "want the flag named, got: {err}");
    fake.join().unwrap();
}

#[test]
fn coordinator_rejects_a_device_hello() {
    let cfg = sharded_cfg(4, 2, 2, 1);
    let (shard_end0, coord_end0) = channel::pair("dev-as-shard");
    let (_keep_alive, coord_end1) = channel::pair("other");
    let fake = thread::spawn(move || {
        let mut conn = shard_end0;
        let _ = conn.recv().unwrap();
        // a device worker pointed at the coordinator by mistake
        conn.send(&Message::Hello {
            device_id: 0,
            devices: 4,
            shard_len: 32,
            config_fp: 1,
            uplink: "identity".into(),
            downlink: "identity".into(),
            sync: "identity".into(),
            streams_fp: 2,
        })
        .unwrap();
    });
    let mut coordinator = Coordinator::from_experiment(&cfg, "mock").unwrap();
    let mut fleet =
        ShardFleet::new(vec![Box::new(coord_end0), Box::new(coord_end1)]);
    let err = coordinator.run(&mut fleet).unwrap_err();
    assert!(err.contains("device"), "want the role mismatch named, got: {err}");
    fake.join().unwrap();
}

#[test]
fn shard_rejects_mismatched_coordinator_hellos() {
    let cfg = sharded_cfg(4, 2, 2, 1);
    let fp = session_fingerprint(cfg.fingerprint(), "mock");
    let topo = Topology { shards: 2, sync_every: 1 };

    // wrong sync cadence
    let (shard_end, mut coord_end) = channel::pair("c1");
    coord_end
        .send(&Message::ShardHello {
            shard_id: 0,
            shards: 2,
            sync_every: 4,
            config_fp: fp,
            weight: 0,
        })
        .unwrap();
    let err = ShardLink::handshake(
        Box::new(shard_end),
        &topo,
        0,
        100,
        fp,
        cfg.shard_link_streams(0).unwrap(),
    )
    .unwrap_err();
    assert!(err.contains("--shard-sync-every"), "got: {err}");

    // wrong session fingerprint
    let (shard_end, mut coord_end) = channel::pair("c2");
    coord_end
        .send(&Message::ShardHello {
            shard_id: 0,
            shards: 2,
            sync_every: 1,
            config_fp: fp ^ 1,
            weight: 0,
        })
        .unwrap();
    let err = ShardLink::handshake(
        Box::new(shard_end),
        &topo,
        0,
        100,
        fp,
        cfg.shard_link_streams(0).unwrap(),
    )
    .unwrap_err();
    assert!(err.contains("fingerprint"), "got: {err}");

    // a device connected to the coordinator port
    let (shard_end, mut coord_end) = channel::pair("c3");
    coord_end
        .send(&Message::Hello {
            device_id: 1,
            devices: 4,
            shard_len: 32,
            config_fp: 1,
            uplink: "identity".into(),
            downlink: "identity".into(),
            sync: "identity".into(),
            streams_fp: 2,
        })
        .unwrap();
    let err = ShardLink::handshake(
        Box::new(shard_end),
        &topo,
        0,
        100,
        fp,
        cfg.shard_link_streams(0).unwrap(),
    )
    .unwrap_err();
    assert!(err.contains("device"), "got: {err}");
}

/// A device whose global id belongs to another shard's slice is rejected
/// by the device handshake, naming the served range.
#[test]
fn device_on_the_wrong_shard_is_rejected() {
    let cfg = sharded_cfg(4, 2, 2, 1);
    let (train, _) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let worker = mock_worker(&cfg, Arc::new(train), 0).unwrap();
    let (mut dev_end, srv_end) = loopback::pair("wrong-shard");
    dev_end.send(&worker.hello()).unwrap();
    // shard 1 serves global ids 2..4; global id 0 must be bounced
    let shape = FleetShape { global: 4, base: 2, local: 1 };
    let err = handshake(vec![Box::new(srv_end)], shape).unwrap_err();
    assert!(err.contains("wrong shard"), "got: {err}");
}

/// A shard that dies mid-session must fail the coordinator with a typed
/// peer-closed error, never a hang.
#[test]
fn shard_disconnect_surfaces_peer_closed() {
    let cfg = sharded_cfg(4, 2, 4, 1);
    let fp = session_fingerprint(cfg.fingerprint(), "mock");
    let mut coord_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut fakes = Vec::new();
    for k in 0..2usize {
        let (shard_end, coord_end) = channel::pair(&format!("dying{k}"));
        coord_ends.push(Box::new(coord_end));
        let cfg = cfg.clone();
        fakes.push(thread::spawn(move || {
            let topo = cfg.topology();
            let link = ShardLink::handshake(
                Box::new(shard_end),
                &topo,
                k,
                64,
                fp,
                cfg.shard_link_streams(k).unwrap(),
            )
            .unwrap();
            // vanish without a departure notice: the link (and its
            // transport) drops here, mid-tier
            drop(link);
        }));
    }
    let mut coordinator = Coordinator::from_experiment(&cfg, "mock").unwrap();
    let mut fleet = ShardFleet::new(coord_ends);
    let err = coordinator.run(&mut fleet).unwrap_err();
    assert!(
        err.contains("disconnected mid-session") && err.contains("peer closed"),
        "want a typed disconnect, got: {err}"
    );
    for f in fakes {
        f.join().unwrap();
    }
}

/// The acceptance drill for `--checkpoint-dir` / `--resume`: the
/// coordinator dies at a sync-epoch boundary, a fresh one comes up from
/// the on-disk checkpoint, and the shards' loss curves continue exactly
/// where an uninterrupted run would have them — bit for bit.
#[test]
fn coordinator_kill_and_resume_keeps_the_loss_curve() {
    let cfg = sharded_cfg(4, 2, 6, 1);
    let reference = run_sharded_mock(&cfg).unwrap();

    let dir = std::env::temp_dir().join(format!(
        "slacc-resume-test-{}-{:?}",
        std::process::id(),
        thread::current().id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    // kill after 3 of 6 sync epochs: the successor knows nothing but the
    // checkpoint on disk
    let resumed = run_sharded_mock_resumed(&cfg, 3, &dir).unwrap();

    assert_eq!(resumed.shard_reports.len(), 2);
    for (k, (res, base)) in
        resumed.shard_reports.iter().zip(&reference.shard_reports).enumerate()
    {
        assert_eq!(res.rounds_run, base.rounds_run, "shard {k}");
        assert_eq!(res.metrics.len(), base.metrics.len(), "shard {k}");
        for (a, b) in res.metrics.records.iter().zip(&base.metrics.records) {
            let ctx = format!("shard {k} round {}", a.round);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss drift across resume: {ctx}");
            assert_eq!(a.accuracy, b.accuracy, "accuracy drift across resume: {ctx}");
            assert_eq!(a.bytes_up, b.bytes_up, "uplink drift across resume: {ctx}");
            assert_eq!(a.bytes_sync, b.bytes_sync, "sync drift across resume: {ctx}");
        }
    }
    // the successor finished the remaining epochs; its byte counters only
    // cover the post-resume half of the session
    assert_eq!(resumed.coordinator.sync_epochs, reference.coordinator.sync_epochs);
    assert!(resumed.coordinator.bytes_up > 0);
    assert!(resumed.coordinator.bytes_up < reference.coordinator.bytes_up);
    // the final checkpoint covers the whole session, with no tmp litter
    let ck = Checkpoint::load(&dir).unwrap();
    assert_eq!(ck.epochs_done, 6);
    assert!(!dir.join("coordinator.ckpt.tmp").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--shard-sync-every K`: shard-link bytes land on the `bytes_sync` axis
/// of sync rounds only, and a larger K moves fewer sync bytes in total.
#[test]
fn shard_sync_cadence_lands_on_the_sync_byte_axis() {
    let every_round = run_sharded_mock(&sharded_cfg(4, 2, 8, 1)).unwrap();
    let amortized = run_sharded_mock(&sharded_cfg(4, 2, 8, 4)).unwrap();

    assert_eq!(every_round.coordinator.sync_epochs, 8);
    assert_eq!(amortized.coordinator.sync_epochs, 2);

    // within the K=4 run: rounds 3 and 7 carry the shard link on top of
    // the device-tier ModelSync traffic every round carries
    for rep in &amortized.shard_reports {
        let recs = &rep.metrics.records;
        assert_eq!(recs.len(), 8);
        for sync_round in [3usize, 7] {
            for plain_round in [0usize, 1, 2] {
                assert!(
                    recs[sync_round].bytes_sync > recs[plain_round].bytes_sync,
                    "round {sync_round} ({}) should out-weigh round {plain_round} ({})",
                    recs[sync_round].bytes_sync,
                    recs[plain_round].bytes_sync
                );
            }
        }
        // the sync ratio axis stays well-defined (raw bytes recorded)
        for r in recs {
            assert!(r.bytes_sync > 0 && r.raw_sync > 0, "round {}", r.round);
        }
    }
    assert!(
        every_round.total_bytes_sync() > amortized.total_bytes_sync(),
        "amortizing the cadence must shrink the sync byte axis: {} vs {}",
        every_round.total_bytes_sync(),
        amortized.total_bytes_sync()
    );
    // the smashed-data axes exist on both (they are not compared: shard
    // models drift between merges, so envelope sizes may differ)
    assert!(every_round.shard_reports[0].total_bytes_up > 0);
    assert!(amortized.shard_reports[0].total_bytes_up > 0);
}

/// TCP cluster == channel-transport simulation, byte for byte: the
/// shard-tier twin of the loopback/TCP parity goldens.
#[test]
fn tcp_two_shard_cluster_matches_the_loopback_sim() {
    let cfg = sharded_cfg(4, 2, 4, 1);
    let reference = run_sharded_mock(&cfg).unwrap();

    let mut dev_addrs = Vec::new();
    let mut shard_addrs = Vec::new();
    let mut dev_listeners = Vec::new();
    let mut shard_listeners = Vec::new();
    for _ in 0..2 {
        let dl = TcpListener::bind("127.0.0.1:0").unwrap();
        let sl = TcpListener::bind("127.0.0.1:0").unwrap();
        dev_addrs.push(dl.local_addr().unwrap().to_string());
        shard_addrs.push(sl.local_addr().unwrap().to_string());
        dev_listeners.push(dl);
        shard_listeners.push(sl);
    }

    let mut shard_handles = Vec::new();
    for (k, (dev_l, shard_l)) in
        dev_listeners.into_iter().zip(shard_listeners).enumerate()
    {
        let cfg = cfg.clone();
        shard_handles.push(thread::spawn(move || -> Result<TrainReport, String> {
            let topo = cfg.topology();
            let conn = TcpTransport::accept_direct(&shard_l)?;
            let (train, test) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let weight = slacc::shard::shard_weight(&cfg, &train, k);
            let fp = session_fingerprint(cfg.fingerprint(), "mock");
            let link = ShardLink::handshake(
                Box::new(conn),
                &topo,
                k,
                weight,
                fp,
                cfg.shard_link_streams(k)?,
            )?;
            let mut rt = mock_runtime_for_shard(&cfg, k, Arc::new(test))?;
            rt.attach_shard_link(link);
            accept_and_serve(&mut rt, &dev_l)
        }));
    }

    let coord_cfg = cfg.clone();
    let coord = thread::spawn(move || -> Result<CoordReport, String> {
        let mut conns: Vec<Box<dyn Transport>> = Vec::new();
        for addr in &shard_addrs {
            conns.push(Box::new(TcpTransport::connect_retry(
                addr,
                80,
                Duration::from_millis(100),
            )?));
        }
        let mut coordinator = Coordinator::from_experiment(&coord_cfg, "mock")?;
        let mut fleet = ShardFleet::new(conns);
        coordinator.run(&mut fleet)
    });

    let mut dev_handles = Vec::new();
    for g in 0..4usize {
        let cfg = cfg.clone();
        let addr = dev_addrs[g / 2].clone();
        dev_handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), g)?;
            let mut conn =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))?;
            run_blocking(&mut worker, &mut conn)
        }));
    }

    let mut tcp_reports = Vec::new();
    for (k, h) in shard_handles.into_iter().enumerate() {
        tcp_reports.push(h.join().unwrap().unwrap_or_else(|e| panic!("shard {k}: {e}")));
    }
    let tcp_coord = coord.join().unwrap().unwrap();
    for (g, h) in dev_handles.into_iter().enumerate() {
        h.join().unwrap().unwrap_or_else(|e| panic!("device {g}: {e}"));
    }

    for (k, (tcp, sim)) in
        tcp_reports.iter().zip(&reference.shard_reports).enumerate()
    {
        assert_eq!(tcp.metrics.len(), sim.metrics.len(), "shard {k}");
        for (a, b) in tcp.metrics.records.iter().zip(&sim.metrics.records) {
            let ctx = format!("shard {k} round {}", a.round);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss drift: {ctx}");
            assert_eq!(a.bytes_up, b.bytes_up, "uplink drift: {ctx}");
            assert_eq!(a.bytes_down, b.bytes_down, "downlink drift: {ctx}");
            assert_eq!(a.bytes_sync, b.bytes_sync, "sync drift: {ctx}");
            assert_eq!(a.accuracy, b.accuracy, "accuracy drift: {ctx}");
        }
    }
    assert_eq!(tcp_coord.sync_epochs, reference.coordinator.sync_epochs);
    assert_eq!(tcp_coord.bytes_up, reference.coordinator.bytes_up);
    assert_eq!(tcp_coord.bytes_down, reference.coordinator.bytes_down);
    assert_eq!(tcp_coord.per_shard, reference.coordinator.per_shard);
}
