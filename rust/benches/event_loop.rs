//! Event-loop scale curve — the 10k-devices-per-shard readiness bench.
//!
//!     cargo bench --bench event_loop            # full 256→10k sweep
//!     cargo bench --bench event_loop -- --smoke # seconds-fast CI smoke
//!
//! Two sweeps, both over every readiness backend (`epoll` and `poll` on
//! linux, `poll` elsewhere):
//!
//! * **wakeup** — the dispatch-cost curve the epoll rework exists for. A
//!   [`Poller`] holds `n` registered connections of which only 8 are ever
//!   active; each iteration writes one byte into the 8 active sockets and
//!   times wakeup → ready-token dispatch → drain. `poll(2)` scans all `n`
//!   descriptors per wakeup (cost grows with fleet size), edge-triggered
//!   epoll returns only the ready 8 (cost stays flat) — the measured
//!   crossover is the row pair to look at. Idle descriptors are `dup`s of
//!   one never-written socket, so 10 000 registrations fit comfortably in
//!   the fd budget.
//! * **soak** — end-to-end scripted fleets through the real
//!   [`PollFleet`] echo harness (`slacc::sched::soak`), reporting wall
//!   time per fleet size; the harness verifies every payload byte and
//!   that per-device wire accounting is uniform across the fleet.
//!
//! Results land in `BENCH_scale.json` (committed) via the shared recorder
//! in `benches/common.rs` on full runs; the smoke subset asserts dispatch
//! correctness (exactly the 8 active tokens surface, idle connections
//! never fire) and leaves the file untouched. Wall clock is reported,
//! never asserted — shared runners are noisy.

#[path = "common.rs"]
mod common;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use slacc::bench::Table;
use slacc::sched::event_loop::FleetOptions;
use slacc::sched::poll::{Backend, Poller};
use slacc::sched::soak::{run_soak, SoakConfig};
use slacc::util::json::Json;

/// Active (traffic-bearing) connections in the wakeup sweep; everything
/// past these is registered but idle.
const ACTIVE: usize = 8;

fn backends() -> Vec<Backend> {
    if cfg!(target_os = "linux") {
        vec![Backend::Epoll, Backend::Poll]
    } else {
        vec![Backend::Poll]
    }
}

/// One accepted loopback pair: (client end, non-blocking server end).
fn socket_pair(listener: &TcpListener) -> (TcpStream, TcpStream) {
    let addr = listener.local_addr().expect("listener addr");
    let client = TcpStream::connect(addr).expect("connect");
    client.set_nodelay(true).expect("nodelay");
    let (server, _) = listener.accept().expect("accept");
    server.set_nonblocking(true).expect("nonblocking");
    (client, server)
}

/// Time `iters` dispatch cycles of a `conns`-connection interest set with
/// [`ACTIVE`] hot sockets; returns mean ns per cycle.
fn wakeup_cycle_ns(backend: Backend, conns: usize, iters: usize) -> f64 {
    assert!(conns > ACTIVE, "need room for idle connections");
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let mut poller = Poller::new(backend).expect("poller");

    let mut clients = Vec::with_capacity(ACTIVE);
    let mut servers = Vec::with_capacity(ACTIVE);
    for token in 0..ACTIVE {
        let (client, server) = socket_pair(&listener);
        poller.register(&server, token).expect("register active");
        clients.push(client);
        servers.push(server);
    }
    // idle bulk: dups of one never-written pair — real descriptors in the
    // interest set that never become ready (both ends held open)
    let (_idle_client, idle_server) = socket_pair(&listener);
    let mut idle = Vec::with_capacity(conns - ACTIVE);
    for token in ACTIVE..conns {
        let dup = idle_server.try_clone().expect("dup idle socket");
        poller.register(&dup, token).expect("register idle");
        idle.push(dup);
    }
    assert_eq!(poller.armed(), conns);

    let mut scratch = [0u8; 256];
    let t0 = Instant::now();
    for _ in 0..iters {
        for client in &mut clients {
            client.write_all(&[0xA5]).expect("poke");
        }
        let mut drained = 0usize;
        while drained < ACTIVE {
            let ready = poller.wait(1000).expect("wait");
            assert!(ready > 0, "wakeup timed out with pokes in flight");
            for k in 0..ready {
                let token = poller.ready_token(k);
                assert!(token < ACTIVE, "idle connection {token} fired");
                loop {
                    match servers[token].read(&mut scratch) {
                        Ok(0) => panic!("active connection {token} hit EOF"),
                        Ok(n) => drained += n,
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) => panic!("drain {token}: {e}"),
                    }
                }
            }
        }
        assert_eq!(drained, ACTIVE, "dispatch lost bytes");
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn sweep(sizes: &[usize], soak_sizes: &[usize], iters: usize, rounds: usize, full: bool) {
    let mut table = Table::new(
        "event loop: wakeup dispatch and fleet soak vs. registered connections",
        &["kind", "backend", "conns", "active", "ns_per_cycle", "wall_s"],
    );
    let mut rec = common::BenchRecorder::new("scale");

    for &conns in sizes {
        for backend in backends() {
            let ns = wakeup_cycle_ns(backend, conns, iters);
            table.row(vec![
                "wakeup".to_string(),
                backend.as_str().to_string(),
                conns.to_string(),
                ACTIVE.to_string(),
                format!("{ns:.0}"),
                "-".to_string(),
            ]);
            rec.row(vec![
                ("kind", Json::Str("wakeup".to_string())),
                ("backend", Json::Str(backend.as_str().to_string())),
                ("conns", Json::Num(conns as f64)),
                ("active", Json::Num(ACTIVE as f64)),
                ("ns_per_cycle", Json::Num(ns)),
                ("wall_s", Json::Null),
            ]);
        }
    }

    for &devices in soak_sizes {
        // a full TCP pair per soak device: stay within default fd budgets
        // here; the 10k end-to-end path is the `scale_soak_10k_devices`
        // integration test (needs a raised ulimit)
        let devices = if devices > 4096 {
            println!("[soak clamped to 4096 devices — fd budget; see scale_soak_10k_devices]");
            4096
        } else {
            devices
        };
        for backend in backends() {
            let mut cfg = SoakConfig::new(devices, rounds);
            cfg.driver_threads = 8;
            cfg.opts = FleetOptions { backend, write_stall_secs: 10, elastic: false };
            let report = run_soak(&cfg)
                .unwrap_or_else(|e| panic!("soak {devices} on {backend:?}: {e}"));
            let golden = report.per_device[0];
            for stats in &report.per_device {
                assert_eq!(*stats, golden, "soak traffic must be uniform");
            }
            table.row(vec![
                "soak".to_string(),
                report.backend.to_string(),
                devices.to_string(),
                devices.to_string(),
                "-".to_string(),
                format!("{:.3}", report.wall_s),
            ]);
            rec.row(vec![
                ("kind", Json::Str("soak".to_string())),
                ("backend", Json::Str(report.backend.to_string())),
                ("conns", Json::Num(devices as f64)),
                ("active", Json::Num(devices as f64)),
                ("ns_per_cycle", Json::Null),
                ("wall_s", Json::Num(report.wall_s)),
            ]);
        }
    }

    table.finish();
    if full {
        // only the full sweep updates the committed perf-trajectory file;
        // the CI smoke subset must not clobber it with its reduced grid
        rec.write();
    } else {
        println!("[smoke mode: BENCH_scale.json left untouched]");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("[event_loop bench: smoke mode]");
        // CI gate: O(ready) dispatch correctness on both backends (idle
        // connections never fire, no lost bytes) and a clean small soak
        sweep(&[256, 1024], &[256], 200, 2, false);
    } else {
        sweep(
            &[256, 1024, 4096, 10_000],
            &[256, 1024, 4096],
            common::env_usize("SLACC_BENCH_WAKEUPS", 2000),
            2,
            true,
        );
    }
}
