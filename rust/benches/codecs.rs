//! Codec stream-pipeline benchmarks — engine-free, runs anywhere and in CI.
//!
//!     cargo bench --bench codecs            # full sweep
//!     cargo bench --bench codecs -- --smoke # seconds-fast CI smoke
//!
//! Two angles:
//! * **throughput** — per-codec encode/decode MB/s at a realistic smashed
//!   data shape, through the reusable-buffer [`Codec::encode`] path.
//! * **allocation** — a counting global allocator audits the steady-state
//!   encode path. The redesign's contract: once the caller-owned buffer
//!   and the codec's internal scratch are warmed, the pure quantization
//!   codecs (`identity`, `uniform*`) perform **zero** allocations per
//!   encode — asserted here, so a regression fails CI. The adaptive codecs
//!   (slacc's clustering/diagnostics, selection, randtopk's index sort)
//!   allocate by design; their counts are reported so drift is visible.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use slacc::codecs::{self, Codec, RoundCtx};
use slacc::entropy::shannon;
use slacc::quant::payload::ByteWriter;
use slacc::tensor::Tensor;
use slacc::util::rng::Pcg32;

/// Counts every allocation/reallocation so the bench can assert the
/// zero-alloc contract of the reusable-buffer encode path.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Specs the sweep covers: every base family plus a wrapped and a
/// parameterized spec, all resolved through the registry.
const SPECS: &[&str] = &[
    "identity", "uniform4", "uniform8", "slacc", "powerquant", "randtopk",
    "splitfc", "easyquant", "select:std:4", "ef:uniform4",
];

/// Codecs whose steady-state encode path must not allocate at all.
const ZERO_ALLOC: &[&str] = &["identity", "uniform4", "uniform8"];

fn activations(b: usize, c: usize, h: usize, w: usize) -> Tensor {
    let mut rng = Pcg32::seeded(1);
    let data: Vec<f32> = (0..b * c * h * w)
        .map(|_| rng.next_gaussian().max(0.0))
        .collect();
    Tensor::new(vec![b, c, h, w], data)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (b, c, h, w, iters) = if smoke {
        println!("[codecs bench: smoke mode]");
        (8usize, 16usize, 8usize, 8usize, 5usize)
    } else {
        // the artifact shape: 1 MiB of smashed data
        (32, 32, 16, 16, 30)
    };
    let acts = activations(b, c, h, w);
    let cm = acts.to_channel_major();
    let ent = shannon::entropies(&cm);
    let raw_bytes = cm.data().len() * 4;

    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>12} {:>12}",
        "spec", "wire_B", "enc MB/s", "dec MB/s", "allocs/enc", "steady"
    );
    for spec in SPECS {
        let mut codec: Box<dyn Codec> =
            codecs::by_name(spec, c, 1000, 3).unwrap_or_else(|e| panic!("{spec}: {e}"));
        let mut buf = ByteWriter::new();
        let ctx = || RoundCtx { entropy: Some(&ent), kind: None };

        // warm the reusable buffer + internal scratch to steady state
        for _ in 0..3 {
            buf.clear();
            codec.encode(&cm, ctx(), &mut buf);
        }
        let wire_len = buf.len();

        // steady-state allocation audit
        let a0 = allocs();
        for _ in 0..iters {
            buf.clear();
            codec.encode(&cm, ctx(), &mut buf);
        }
        let per_encode = (allocs() - a0) as f64 / iters as f64;
        let steady_ok = per_encode == 0.0;
        if ZERO_ALLOC.contains(spec) {
            assert!(
                steady_ok,
                "{spec}: {per_encode} allocations per steady-state encode \
                 (reusable-buffer contract broken)"
            );
        }

        // encode throughput
        let t0 = Instant::now();
        for _ in 0..iters {
            buf.clear();
            codec.encode(&cm, ctx(), &mut buf);
        }
        let enc_mbs = raw_bytes as f64 * iters as f64 / t0.elapsed().as_secs_f64() / 1e6;

        // decode throughput
        let wire = buf.to_vec();
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(codec.decode(&wire).unwrap());
        }
        let dec_mbs = raw_bytes as f64 * iters as f64 / t0.elapsed().as_secs_f64() / 1e6;

        println!(
            "{:<16} {:>8} {:>10.1} {:>10.1} {:>12.1} {:>12}",
            spec,
            wire_len,
            enc_mbs,
            dec_mbs,
            per_encode,
            if steady_ok { "zero-alloc" } else { "allocates" }
        );
    }
    // --- entropy hot path: per-channel entropies into caller scratch ---
    // `entropies_into` backs ACII on every uplink/downlink tensor; with a
    // warmed caller-owned buffer its steady state must not allocate at all
    // (the per-channel kernel fuses min/max into its first pass and never
    // materializes the softmax).
    let mut ent_scratch: Vec<f32> = Vec::new();
    shannon::entropies_into(&cm, &mut ent_scratch);
    assert_eq!(
        ent_scratch, ent,
        "entropies_into diverged from the allocating path"
    );
    let a0 = allocs();
    for _ in 0..iters {
        shannon::entropies_into(&cm, &mut ent_scratch);
    }
    let ent_allocs = (allocs() - a0) as f64 / iters as f64;
    assert!(
        ent_allocs == 0.0,
        "entropies_into: {ent_allocs} allocations per warmed call \
         (caller-scratch contract broken)"
    );
    let t0 = Instant::now();
    for _ in 0..iters {
        shannon::entropies_into(&cm, &mut ent_scratch);
        std::hint::black_box(&ent_scratch);
    }
    let ent_mbs = raw_bytes as f64 * iters as f64 / t0.elapsed().as_secs_f64() / 1e6;
    println!(
        "\n{:<16} {:>8} {:>10.1} {:>10} {:>12.1} {:>12}",
        "entropies_into", c, ent_mbs, "-", ent_allocs, "zero-alloc"
    );

    // --- sync pack: one payload allocation per pack, scratch reused ---
    // the FedAvg broadcast loop packs once per device per agg round; with
    // a warmed SyncScratch the only allocation left is the returned
    // payload itself (exact-capacity, no growth).
    let params = vec![
        slacc::tensor::Tensor::new(vec![c, 16], vec![0.25; c * 16]),
        slacc::tensor::Tensor::new(vec![c], vec![-0.5; c]),
    ];
    let mut sync_codec = codecs::by_name("identity", 1, 1000, 3).unwrap();
    let mut sync_scratch = slacc::transport::sync::SyncScratch::default();
    let warm = slacc::transport::sync::pack_params_with(
        &params,
        sync_codec.as_mut(),
        &mut sync_scratch,
    );
    let a0 = allocs();
    for _ in 0..iters {
        std::hint::black_box(slacc::transport::sync::pack_params_with(
            &params,
            sync_codec.as_mut(),
            &mut sync_scratch,
        ));
    }
    let pack_allocs = (allocs() - a0) as f64 / iters as f64;
    assert!(
        pack_allocs <= 1.0,
        "pack_params_with: {pack_allocs} allocations per warmed pack \
         (want exactly the returned payload)"
    );
    println!(
        "{:<16} {:>8} {:>10} {:>10} {:>12.1} {:>12}",
        "sync pack",
        warm.len(),
        "-",
        "-",
        pack_allocs,
        "payload-only"
    );

    println!(
        "\nzero-alloc contract held for {:?} + entropies_into + sync pack \
         ({} iters at {}x{}x{}x{})",
        ZERO_ALLOC, iters, b, c, h, w
    );
}
