//! Scheduler / event-loop benchmarks — engine-free (mock compute), so they
//! run on any machine and in CI.
//!
//!     cargo bench --bench sched            # full sweep
//!     cargo bench --bench sched -- --smoke # seconds-fast CI smoke
//!
//! Three angles:
//! * **policy** — the same heterogeneous 5-device fleet (one 10x-slower
//!   straggler) under InOrder, ArrivalOrder, and ArrivalOrder + straggler
//!   timeout, on the deterministic loopback delay shim: simulated
//!   time-to-accuracy is the paper's axis, and the timeout policy must win
//!   it by not paying the straggler's link every round.
//! * **event loop** — real sockets: N mock devices against the
//!   single-threaded poll server, wall seconds per session.
//! * **decoder** — the incremental frame decoder's reassembly throughput
//!   (it sits on every byte the event loop reads).

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use slacc::bench::{Bencher, Table};
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::data::Dataset;
use slacc::sched::{Participation, Policy};
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::proto::{FrameDecoder, Message};
use slacc::transport::server::{accept_and_serve, mock_runtime, run_mock_loopback_delayed};
use slacc::transport::tcp::TcpTransport;

fn bench_cfg(devices: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 64.max(devices * 4);
    cfg.test_n = 16;
    cfg.eval_every = rounds.max(1);
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg
}

fn policy_comparison(rounds: usize) {
    let mut table = Table::new(
        "sched: policy comparison (1 straggler @ 10x slow)",
        &["policy", "rounds", "final_acc%", "sim_time_s", "stragglers", "sync_KB"],
    );
    let policies = [
        ("inorder", Policy::InOrder, Participation::All),
        ("arrival", Policy::arrival(), Participation::All),
        ("arrival+timeout", Policy::arrival_with_timeout(0.08, 4), Participation::All),
        // `--select bias-stragglers`: the chronic straggler sits out every
        // other round, so the fleet stops burning its timeout twice per
        // cadence — same accuracy axis, lower simulated time-to-accuracy
        (
            "bias-stragglers",
            Policy::arrival_with_timeout(0.08, 4),
            Participation::BiasStragglers,
        ),
    ];
    for (name, policy, participation) in policies {
        let mut cfg = bench_cfg(5, rounds);
        cfg.schedule = policy;
        cfg.participation = participation;
        // the cost model sees a 10x-slower link; the delay shim makes the
        // same device actually arrive late so the timeout policy engages
        cfg.device_speeds = vec![1.0, 1.0, 1.0, 1.0, 0.1];
        let delays = [0.005, 0.005, 0.005, 0.005, 0.5];
        let (report, _) = run_mock_loopback_delayed(&cfg, &delays, 11)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        table.row(vec![
            name.to_string(),
            report.rounds_run.to_string(),
            format!("{:.2}", report.final_accuracy * 100.0),
            format!("{:.2}", report.total_sim_time_s),
            report.straggler_events.to_string(),
            format!("{:.1}", report.total_bytes_sync as f64 / 1e3),
        ]);
    }
    table.finish();
}

fn event_loop_session(devices: usize, rounds: usize) -> f64 {
    let cfg = bench_cfg(devices, rounds);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut handles = Vec::new();
    for d in 0..devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)
                    .unwrap();
            let mut worker = mock_worker(&cfg, Arc::new(train), d).unwrap();
            let mut conn =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))
                    .unwrap();
            run_blocking(&mut worker, &mut conn).unwrap();
        }));
    }
    let (_, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed).unwrap();
    let mut rt = mock_runtime(&cfg, Arc::new(test)).unwrap();
    let t0 = Instant::now();
    let report = accept_and_serve(&mut rt, &listener).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(report.rounds_run, rounds);
    for h in handles {
        h.join().unwrap();
    }
    wall
}

fn event_loop_scaling(fleets: &[usize], rounds: usize) {
    let mut table = Table::new(
        "sched: poll event loop scaling (mock devices over TCP)",
        &["devices", "rounds", "wall_s", "rounds_per_s"],
    );
    for &devices in fleets {
        let wall = event_loop_session(devices, rounds);
        table.row(vec![
            devices.to_string(),
            rounds.to_string(),
            format!("{wall:.3}"),
            format!("{:.1}", rounds as f64 / wall.max(1e-9)),
        ]);
    }
    table.finish();
}

fn decoder_throughput(samples: usize) {
    let payload = vec![0x5a_u8; 1 << 20];
    let frame = Message::Activations {
        round: 1,
        device_id: 0,
        labels: vec![1; 64],
        payload,
    }
    .encode_frame();
    let frame_len = frame.len();
    let result = Bencher::new("frame decoder, 1 MiB frames in 4 KiB chunks")
        .warmup(2)
        .samples(samples)
        .run_bytes(|| {
            let mut dec = FrameDecoder::new();
            let mut out = 0usize;
            for chunk in frame.chunks(4096) {
                dec.feed(chunk);
                while let Some((_, n)) = dec.next().unwrap() {
                    out += n;
                }
            }
            assert_eq!(out, frame_len);
            out
        });
    println!("{}", result.row());
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // `cargo bench` forwards a `--bench` flag; ignore anything unknown
    if smoke {
        println!("[sched bench: smoke mode]");
        policy_comparison(4);
        event_loop_scaling(&[4], 2);
        decoder_throughput(3);
    } else {
        policy_comparison(20);
        event_loop_scaling(&[8, 32, 64], 5);
        decoder_throughput(20);
    }
}
