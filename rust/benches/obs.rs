//! Telemetry hot-path benchmarks — engine-free, runs anywhere and in CI.
//!
//!     cargo bench --bench obs            # full run (updates BENCH_obs.json)
//!     cargo bench --bench obs -- --smoke # seconds-fast CI smoke
//!
//! Two angles:
//! * **per-op cost + allocation** — every instrument class (counter add,
//!   gauge set, histogram observe, disabled span, enabled span) is timed
//!   and audited by a counting global allocator. The subsystem's contract:
//!   once a thread's span ring is registered, **zero** allocations per
//!   operation on every hot path — asserted here, so a regression fails CI.
//! * **overhead** — a realistic codec encode loop with the exact call-site
//!   instrumentation pattern (`Instant::now` + `record_encode` + a disabled
//!   span) versus the same loop bare. The claim: instrumentation costs
//!   ≤ 2% end to end. Min-of-N wall clock on both sides; the ratio is
//!   asserted in full runs only (shared CI runners are too noisy for
//!   timing assertions — the smoke run still audits allocations).

#[path = "common.rs"]
mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use slacc::codecs::stream::{record_encode, record_entropy, StreamKind};
use slacc::codecs::{self, Codec, RoundCtx};
use slacc::entropy::shannon;
use slacc::obs::{metrics, span};
use slacc::quant::payload::ByteWriter;
use slacc::sched::event_loop::{FleetOptions, PollFleet};
use slacc::sched::fleet::Fleet;
use slacc::shard::FleetShape;
use slacc::tensor::Tensor;
use slacc::transport::proto::Message;
use slacc::util::json::Json;
use slacc::util::rng::Pcg32;

/// Counts every allocation/reallocation so the bench can assert the
/// zero-alloc contract of the telemetry hot paths.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Min-of-`reps` nanoseconds per call of `f` over `iters`-call batches.
fn min_ns_per_op<F: FnMut()>(iters: usize, reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    best
}

/// Allocations per call of `f` over one `iters`-call batch.
fn allocs_per_op<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let a0 = allocs();
    for _ in 0..iters {
        f();
    }
    (allocs() - a0) as f64 / iters as f64
}

fn activations(b: usize, c: usize, h: usize, w: usize) -> Tensor {
    let mut rng = Pcg32::seeded(1);
    let data: Vec<f32> = (0..b * c * h * w)
        .map(|_| rng.next_gaussian().max(0.0))
        .collect();
    Tensor::new(vec![b, c, h, w], data)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (op_iters, reps, b, c, h, w, enc_iters) = if smoke {
        println!("[obs bench: smoke mode]");
        (100_000usize, 3usize, 8usize, 16usize, 8usize, 8usize, 40usize)
    } else {
        (1_000_000, 5, 32, 32, 16, 16, 30)
    };
    let mut rec = common::BenchRecorder::new("obs");

    // ---- per-op cost + zero-alloc audit -------------------------------
    // warm every path to steady state: OnceLock epoch, this thread's span
    // ring (one bounded registration allocation), the instruments themselves
    span::set_enabled(true);
    {
        let _warm = slacc::span!("warmup", i = 0);
    }
    span::set_enabled(false);
    metrics::POLL_WAKEUPS.inc();
    metrics::QUEUE_DEPTH.set(1);
    metrics::DISPATCH_WIDTH.observe(1);

    println!("{:<24} {:>10} {:>12}", "hot path", "ns/op", "allocs/op");
    let mut audit = |name: &str, enabled: bool, f: &mut dyn FnMut()| {
        span::set_enabled(enabled);
        let per_op = allocs_per_op(op_iters, &mut *f);
        assert!(
            per_op == 0.0,
            "{name}: {per_op} allocations per op (telemetry hot path must \
             not allocate)"
        );
        let ns = min_ns_per_op(op_iters, reps, &mut *f);
        span::set_enabled(false);
        println!("{name:<24} {ns:>10.1} {per_op:>12.1}");
        rec.row(vec![
            ("path", Json::Str(name.to_string())),
            ("ns_per_op", Json::Num(ns)),
            ("allocs_per_op", Json::Num(per_op)),
        ]);
    };
    audit("counter add", false, &mut || metrics::POLL_WAKEUPS.add(1));
    audit("gauge set", false, &mut || metrics::QUEUE_DEPTH.set(7));
    audit("histogram observe", false, &mut || {
        metrics::DISPATCH_WIDTH.observe(13)
    });
    audit("span (disabled)", false, &mut || {
        let _s = slacc::span!("bench_tick", round = 3, gid = 7, i = 1);
    });
    audit("span (enabled)", true, &mut || {
        let _s = slacc::span!("bench_tick", round = 3, gid = 7, i = 1);
    });
    audit("span (manual record)", true, &mut || {
        span::record(
            span::SpanEvent::manual("bench_wait", 10, 5).round(3).gid(7),
        );
    });
    audit("entropy drift record", false, &mut || {
        record_entropy(StreamKind::Uplink, &[1.5, 2.5, 3.5, 4.5]);
    });
    let _ = span::drain(); // discard the audit's ring contents

    // ---- event-loop hot path: wakeup → decode-in-place → dispatch -----
    // the epoll rework's steady-state contract: once the connection slab,
    // decoder rings, and inboxes are warm, one readiness wakeup →
    // in-place frame decode → recv_any dispatch performs zero heap
    // allocations. A real TCP client paces small RoundOpen frames in
    // inbox-sized bursts (so the decoder ring never outgrows its retained
    // capacity and the measurement is genuinely steady-state), and the
    // fleet is pulled through the public recv_any path.
    {
        let hot_iters = 20_000usize;
        let hot_warmup = 2_000usize;
        let hot_reps = reps;
        let total = hot_warmup + hot_iters * (hot_reps + 1);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("listener addr");
        let client = std::thread::spawn(move || {
            use std::io::{Read, Write};
            let specs = slacc::codecs::stream::StreamSpecs::parse(
                "identity", "identity", "identity",
            )
            .expect("identity specs");
            let hello = Message::Hello {
                device_id: 0,
                devices: 1,
                shard_len: 8,
                config_fp: 1,
                uplink: specs.uplink.as_str().to_string(),
                downlink: specs.downlink.as_str().to_string(),
                sync: specs.sync.as_str().to_string(),
                streams_fp: specs.fingerprint(),
            }
            .encode_frame();
            let frame = Message::RoundOpen { round: 9, sync: false }.encode_frame();
            let mut sock = std::net::TcpStream::connect(addr).expect("connect");
            sock.set_nodelay(true).expect("nodelay");
            sock.write_all(&hello).expect("hello");
            for k in 0..total {
                sock.write_all(&frame).expect("frame");
                if k % 8 == 7 {
                    // burst pacing: stay under the server's inbox cap so
                    // the decoder ring holds a handful of frames, not the
                    // whole backlog
                    std::thread::sleep(std::time::Duration::from_micros(20));
                }
            }
            // hold our end open until the server drops the fleet
            let mut eof = [0u8; 16];
            while sock.read(&mut eof).map(|n| n > 0).unwrap_or(false) {}
        });
        let (mut fleet, _hellos) =
            PollFleet::accept_with(&listener, FleetShape::flat(1), FleetOptions::default())
                .expect("accept fleet");
        let mut pull = || match fleet.recv_any(None) {
            Ok(Some((0, Message::RoundOpen { round: 9, .. }))) => {}
            other => panic!("event-loop audit: unexpected frame: {other:?}"),
        };
        for _ in 0..hot_warmup {
            pull();
        }
        let a0 = allocs();
        for _ in 0..hot_iters {
            pull();
        }
        let per_op = (allocs() - a0) as f64 / hot_iters as f64;
        assert!(
            per_op == 0.0,
            "event-loop hot path: {per_op} allocations per dispatched frame \
             (wakeup → decode-in-place → recv_any must not allocate at \
             steady state)"
        );
        let mut best = f64::INFINITY;
        for _ in 0..hot_reps {
            let t0 = Instant::now();
            for _ in 0..hot_iters {
                pull();
            }
            best = best.min(t0.elapsed().as_nanos() as f64 / hot_iters as f64);
        }
        println!("{:<24} {best:>10.1} {per_op:>12.1}", "event loop recv (paced)");
        rec.row(vec![
            ("path", Json::Str("event_loop_recv".to_string())),
            ("ns_per_op", Json::Num(best)),
            ("allocs_per_op", Json::Num(per_op)),
        ]);
        drop(fleet);
        client.join().expect("audit client thread");
    }

    // ---- overhead: instrumented vs bare codec encode loop -------------
    // the exact device-worker uplink call-site pattern: a clock read before
    // the encode, record_encode after, under a (disabled) span
    let acts = activations(b, c, h, w);
    let cm = acts.to_channel_major();
    let ent = shannon::entropies(&cm);
    let raw_bytes = cm.data().len() * 4;
    let mut codec: Box<dyn Codec> =
        codecs::by_name("uniform4", c, 1000, 3).unwrap_or_else(|e| panic!("uniform4: {e}"));
    let mut buf = ByteWriter::new();
    for _ in 0..3 {
        buf.clear();
        codec.encode(&cm, RoundCtx { entropy: Some(&ent), kind: None }, &mut buf);
    }

    let mut best_bare = f64::INFINITY;
    let mut best_instr = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..enc_iters {
            buf.clear();
            codec.encode(&cm, RoundCtx { entropy: Some(&ent), kind: None }, &mut buf);
        }
        best_bare = best_bare.min(t0.elapsed().as_secs_f64());

        let t0 = Instant::now();
        for _ in 0..enc_iters {
            let _sp = slacc::span!(
                "uplink_encode",
                round = 0,
                gid = 0,
                kind = StreamKind::Uplink,
                bytes = buf.len()
            );
            let enc_t0 = Instant::now();
            buf.clear();
            codec.encode(
                &cm,
                RoundCtx { entropy: Some(&ent), kind: Some(StreamKind::Uplink) },
                &mut buf,
            );
            record_encode(StreamKind::Uplink, enc_t0, buf.len());
        }
        best_instr = best_instr.min(t0.elapsed().as_secs_f64());
    }
    let bare_mbs = raw_bytes as f64 * enc_iters as f64 / best_bare / 1e6;
    let instr_mbs = raw_bytes as f64 * enc_iters as f64 / best_instr / 1e6;
    let overhead = best_instr / best_bare - 1.0;
    println!(
        "\nencode loop ({b}x{c}x{h}x{w}, uniform4): bare {bare_mbs:.1} MB/s, \
         instrumented {instr_mbs:.1} MB/s, overhead {:.2}%",
        overhead * 100.0
    );
    rec.row(vec![
        ("path", Json::Str("encode_loop_overhead".to_string())),
        ("bare_mb_s", Json::Num(bare_mbs)),
        ("instrumented_mb_s", Json::Num(instr_mbs)),
        ("overhead_frac", Json::Num(overhead)),
    ]);
    if smoke {
        // CI gate: the allocation asserts above fail the job; the timing
        // ratio is asserted only in full runs (shared runners are too noisy)
        println!("[smoke mode: overhead reported, asserted only in full runs]");
        println!("[smoke mode: BENCH_obs.json left untouched]");
    } else {
        assert!(
            overhead <= 0.02,
            "instrumented encode loop is {:.2}% slower than bare \
             (telemetry contract: <= 2%)",
            overhead * 100.0
        );
        rec.write();
    }
}
