//! Shared setup for the figure-regeneration benches.
//!
//! Every bench runs *real training* through the full stack (PJRT artifacts,
//! codecs, network sim). Workload size is scaled for a CPU testbed and can
//! be grown toward the paper's scale via environment variables:
//!
//!   SLACC_BENCH_ROUNDS   training rounds per run      (default 40)
//!   SLACC_BENCH_TRAIN_N  training samples             (default 400)
//!   SLACC_BENCH_DEVICES  edge devices                 (default paper's 5)
//!
//! The *shape* of each figure (orderings, crossovers) is what the bench
//! asserts/reports; absolute accuracies at these budgets are below the
//! paper's 300-round GPU numbers. See EXPERIMENTS.md for recorded runs.

#![allow(dead_code)]

use slacc::config::ExperimentConfig;
use slacc::coordinator::trainer::{TrainReport, Trainer};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn rounds() -> usize {
    env_usize("SLACC_BENCH_ROUNDS", 40)
}

pub fn train_n() -> usize {
    env_usize("SLACC_BENCH_TRAIN_N", 400)
}

pub fn devices() -> usize {
    env_usize("SLACC_BENCH_DEVICES", 5)
}

/// Baseline experiment config for a bench run.
pub fn base_cfg(dataset: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for(dataset);
    cfg.rounds = rounds();
    cfg.train_n = train_n();
    cfg.devices = devices();
    cfg.test_n = 256;
    cfg.eval_every = (rounds() / 8).max(1);
    cfg.lr = 3e-3;
    cfg
}

/// Run one configured experiment, panicking with context on failure.
pub fn run(cfg: ExperimentConfig, label: &str) -> TrainReport {
    eprintln!("[bench] running {label} ...");
    let t0 = std::time::Instant::now();
    let mut trainer =
        Trainer::new(cfg).unwrap_or_else(|e| panic!("{label}: setup failed: {e}"));
    let report = trainer.run().unwrap_or_else(|e| panic!("{label}: run failed: {e}"));
    eprintln!(
        "[bench] {label}: acc {:.2}% in {:.0}s wall",
        report.final_accuracy * 100.0,
        t0.elapsed().as_secs_f64()
    );
    report
}

/// Mean and std of the accuracies at the last `k` eval points (Fig. 3b's
/// stability metric).
pub fn tail_acc_stats(report: &TrainReport, k: usize) -> (f64, f64) {
    let curve = report.metrics.accuracy_curve();
    let tail: Vec<f64> = curve
        .iter()
        .rev()
        .take(k)
        .map(|&(_, a)| a)
        .collect();
    (
        slacc::util::stats::mean(&tail),
        slacc::util::stats::std(&tail),
    )
}

pub fn require_artifacts(dataset: &str) {
    let p = std::path::Path::new("artifacts")
        .join(dataset)
        .join("manifest.json");
    if !p.exists() {
        eprintln!("artifacts/{dataset} missing — run `make artifacts` first");
        std::process::exit(0); // bench "passes" vacuously, like a skip
    }
}

/// Machine-readable bench results, committed next to the crate so the repo
/// accumulates a perf trajectory across PRs (unlike the `bench_results/`
/// sidecars, which are per-run scratch). `write()` emits
/// `BENCH_<name>.json` in the crate root: `{"bench": ..., "rows": [...]}`
/// with one flat object per recorded row.
pub struct BenchRecorder {
    name: String,
    rows: Vec<slacc::util::json::Json>,
}

impl BenchRecorder {
    pub fn new(name: &str) -> BenchRecorder {
        BenchRecorder { name: name.to_string(), rows: Vec::new() }
    }

    /// Record one row of named values.
    pub fn row(&mut self, fields: Vec<(&str, slacc::util::json::Json)>) {
        self.rows.push(slacc::util::json::Json::obj(fields));
    }

    /// Write `BENCH_<name>.json` (cargo bench runs with the crate root as
    /// CWD) and return its path.
    pub fn write(self) -> std::path::PathBuf {
        use slacc::util::json::Json;
        let path = std::path::PathBuf::from(format!("BENCH_{}.json", self.name));
        let doc = Json::obj(vec![
            ("bench", Json::Str(self.name.clone())),
            ("rows", Json::Arr(self.rows)),
        ]);
        std::fs::write(&path, doc.dump()).unwrap_or_else(|e| {
            panic!("write {}: {e}", path.display());
        });
        println!("[saved {}]", path.display());
        path
    }
}
