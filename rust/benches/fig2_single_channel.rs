//! Fig. 2 — motivating experiment: train with a SINGLE channel of the
//! smashed data and show (a) channels contribute unequally to final test
//! accuracy and (b) a channel's contribution varies over training rounds.
//!
//! Paper setup: ResNet-18 / HAM10000 / SFL, one channel transmitted.
//! Here: GN-ResNet-8 / synth-HAM, `Selection::Fixed(c)` codec, a spread of
//! cut-layer channels.
//!
//!     cargo bench --bench fig2_single_channel

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::codecs::selection::Selection;
use slacc::config::CodecChoice;

fn main() {
    common::require_artifacts("ham");
    let channels = [0usize, 8, 16, 24];

    let mut table = Table::new(
        "fig2: single-channel training (synth-HAM, IID)",
        &["channel", "final_acc%", "best_acc%", "mean_loss_tail"],
    );

    let mut curves = Vec::new();
    for &ch in &channels {
        let mut cfg = common::base_cfg("ham");
        cfg.devices = 2; // ablation-scale fleet
        cfg.codec = CodecChoice::Select { strategy: Selection::Fixed(ch), n_select: 1 };
        let report = common::run(cfg, &format!("fig2 channel {ch}"));
        table.row(vec![
            format!("{ch}"),
            format!("{:.2}", report.final_accuracy * 100.0),
            format!("{:.2}", report.best_accuracy * 100.0),
            format!("{:.4}", report.metrics.mean_loss_tail(5)),
        ]);
        let curve: Vec<(f64, f64)> = report
            .metrics
            .accuracy_curve()
            .into_iter()
            .map(|(r, a)| (r as f64, a))
            .collect();
        curves.push((ch, curve));
    }

    // Fig. 2b: accuracy per round for each channel
    for (ch, curve) in &curves {
        table.series(&format!("fig2b_channel_{ch}_acc_vs_round"), curve);
    }

    // paper shape check: channels are NOT equal contributors
    let accs: Vec<f64> = curves
        .iter()
        .map(|(_, c)| c.last().map(|&(_, a)| a).unwrap_or(0.0))
        .collect();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    println!("\nchannel accuracy spread: {:.2}pp (paper: unequal contributions)",
             spread * 100.0);
    table.finish();
}
