//! Fig. 3 — instantaneous vs historical entropy: train transmitting the
//! single channel with the highest instantaneous / historical entropy and
//! compare (a) the accuracy trajectory and (b) its stability (STD of the
//! accuracy over the evaluation tail).
//!
//! Paper shape: instantaneous converges faster early but is less stable /
//! lower final; historical is smoother but adapts more slowly.
//!
//!     cargo bench --bench fig3_entropy_modes

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::codecs::selection::Selection;
use slacc::config::CodecChoice;

fn main() {
    common::require_artifacts("ham");
    let modes = [
        ("instantaneous", Selection::EntropyInstant),
        ("historical", Selection::EntropyHistorical),
    ];

    let mut table = Table::new(
        "fig3: highest-entropy channel selection (synth-HAM, IID)",
        &["mode", "final_acc%", "best_acc%", "tail_acc_mean%", "tail_acc_std%"],
    );

    for (name, strategy) in modes {
        let mut cfg = common::base_cfg("ham");
        cfg.devices = 2;
        cfg.eval_every = (common::rounds() / 16).max(1); // dense eval for STD
        cfg.codec = CodecChoice::Select { strategy, n_select: 1 };
        let report = common::run(cfg, &format!("fig3 {name}"));
        let (tail_mean, tail_std) = common::tail_acc_stats(&report, 6);
        table.row(vec![
            name.to_string(),
            format!("{:.2}", report.final_accuracy * 100.0),
            format!("{:.2}", report.best_accuracy * 100.0),
            format!("{:.2}", tail_mean * 100.0),
            format!("{:.2}", tail_std * 100.0),
        ]);
        let curve: Vec<(f64, f64)> = report
            .metrics
            .accuracy_curve()
            .into_iter()
            .map(|(r, a)| (r as f64, a))
            .collect();
        table.series(&format!("fig3_{name}_acc_vs_round"), &curve);
    }
    table.finish();
}
