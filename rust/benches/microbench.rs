//! §Perf micro-benchmarks: the L3 hot paths (codec compress/decompress,
//! host entropy, relayout, k-means, bit packing) and the PJRT executes
//! (client_fwd / server_step / entropy kernel) at the real smashed-data
//! shape. This is the before/after instrument for EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench microbench

#[path = "common.rs"]
mod common;

use slacc::bench::Bencher;
use slacc::grouping::kmeans_1d;
use slacc::codecs::{self, Codec, RoundCtx};
use slacc::entropy::shannon;
use slacc::quant::bitpack;
use slacc::runtime::{Arg, Engine};
use slacc::tensor::Tensor;
use slacc::util::rng::Pcg32;

fn real_shape_acts(seed: u64) -> Tensor {
    // the artifact shape: (32, 32, 16, 16) = 1 MiB of smashed data
    let (b, c, h, w) = (32usize, 32usize, 16usize, 16usize);
    let mut rng = Pcg32::seeded(seed);
    let data: Vec<f32> = (0..b * c * h * w)
        .map(|_| rng.next_gaussian().max(0.0))
        .collect();
    Tensor::new(vec![b, c, h, w], data)
}

fn main() {
    let acts = real_shape_acts(1);
    let cm = acts.to_channel_major();
    let raw_bytes = cm.data().len() * 4;
    let mut results = Vec::new();

    // --- L3 pure-Rust hot paths ---
    results.push(
        Bencher::new("relayout: NCHW -> channel-major (1 MiB)")
            .run_bytes(|| {
                std::hint::black_box(acts.to_channel_major());
                raw_bytes
            }),
    );
    results.push(
        Bencher::new("host entropy: 32ch x 8192 (mirror of L1 kernel)")
            .run_bytes(|| {
                std::hint::black_box(shannon::entropies(&cm));
                raw_bytes
            }),
    );
    let ent = shannon::entropies(&cm);
    let mut rng = Pcg32::seeded(2);
    results.push(Bencher::new("kmeans_1d: 32 entropies, g=4 (x4 restarts)").run(|| {
        std::hint::black_box(kmeans_1d(&ent, 4, &mut rng));
    }));

    let codes: Vec<u32> = (0..8192u32).map(|i| i % 32).collect();
    results.push(
        Bencher::new("bitpack: 8192 codes @ 5 bits")
            .run_bytes(|| bitpack::pack(&codes, 5).len()),
    );
    let packed = bitpack::pack(&codes, 5);
    results.push(
        Bencher::new("bitunpack: 8192 codes @ 5 bits")
            .run_bytes(|| bitpack::unpack(&packed, 5, 8192).len() * 4),
    );

    for name in ["slacc", "uniform4", "powerquant", "randtopk", "splitfc", "easyquant"] {
        let mut codec = codecs::by_name(name, cm.channels, 1000, 3).unwrap();
        let mut wire = Vec::new();
        results.push(
            Bencher::new(&format!("compress[{name}]: 1 MiB activations"))
                .run_bytes(|| {
                    wire = codec.compress(&cm, RoundCtx { entropy: Some(&ent), kind: None });
                    raw_bytes
                }),
        );
        results.push(
            Bencher::new(&format!("decompress[{name}]"))
                .run_bytes(|| {
                    std::hint::black_box(codec.decode(&wire).unwrap());
                    raw_bytes
                }),
        );
    }

    // --- PJRT executes at the real artifact shape ---
    let dir = std::path::Path::new("artifacts/ham");
    if dir.join("manifest.json").exists() {
        let mut engine = Engine::load(dir).unwrap();
        let man = engine.manifest().clone();
        let cp = man.load_client_init().unwrap();
        let sp = man.load_server_init().unwrap();
        let mut rng = Pcg32::seeded(4);
        let x: Vec<f32> = (0..man.batch * man.in_ch * man.img * man.img)
            .map(|_| rng.next_f32())
            .collect();
        let x_dims = [man.batch, man.in_ch, man.img, man.img];
        let y: Vec<i32> = (0..man.batch).map(|i| (i % man.classes) as i32).collect();
        let y_dims = [man.batch];

        results.push(Bencher::new("pjrt: entropy kernel (Pallas, AOT)").samples(20).run(|| {
            engine
                .execute("entropy", &[Arg::F32(acts.data(), acts.dims())])
                .unwrap();
        }));
        results.push(Bencher::new("pjrt: client_fwd").samples(20).run(|| {
            let mut args: Vec<Arg> =
                cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
            args.push(Arg::F32(&x, &x_dims));
            engine.execute("client_fwd", &args).unwrap();
        }));
        results.push(Bencher::new("pjrt: server_step (fwd+bwd+sgd)").samples(20).run(|| {
            let mut args: Vec<Arg> =
                sp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
            args.push(Arg::F32(acts.data(), acts.dims()));
            args.push(Arg::I32(&y, &y_dims));
            args.push(Arg::ScalarF32(0.001));
            engine.execute("server_step", &args).unwrap();
        }));
        results.push(Bencher::new("pjrt: client_bwd").samples(20).run(|| {
            let mut args: Vec<Arg> =
                cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
            args.push(Arg::F32(&x, &x_dims));
            args.push(Arg::F32(acts.data(), acts.dims()));
            args.push(Arg::ScalarF32(0.001));
            engine.execute("client_bwd", &args).unwrap();
        }));
    } else {
        eprintln!("artifacts/ham missing: skipping PJRT microbenches");
    }

    println!("\n=== microbench ===");
    for r in &results {
        println!("{}", r.row());
    }
}
