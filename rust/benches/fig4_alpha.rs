//! Fig. 4 — the balancing hyperparameter α (Eq. 2/3): accuracy and
//! time-to-accuracy for fixed α ∈ {0, 0.25, 0.5, 0.75, 1.0} vs the paper's
//! adaptive α = t/T, with the full SL-ACC codec active.
//!
//! Paper shape: fixed α trades convergence speed vs final accuracy; the
//! optimal fixed α shifts over training; adaptive t/T dominates.
//!
//!     cargo bench --bench fig4_alpha

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::config::CodecChoice;
use slacc::entropy::AlphaSchedule;

fn main() {
    common::require_artifacts("ham");
    let schedules = [
        ("0.00", Some(AlphaSchedule::Fixed(0.0))),
        ("0.25", Some(AlphaSchedule::Fixed(0.25))),
        ("0.50", Some(AlphaSchedule::Fixed(0.5))),
        ("0.75", Some(AlphaSchedule::Fixed(0.75))),
        ("1.00", Some(AlphaSchedule::Fixed(1.0))),
        ("t/T (adaptive)", None),
    ];

    let mut table = Table::new(
        "fig4: balancing hyperparameter alpha (SL-ACC, synth-HAM, IID)",
        &["alpha", "final_acc%", "best_acc%", "sim_time_s", "time_to_55%_s"],
    );

    for (name, schedule) in schedules {
        let mut cfg = common::base_cfg("ham");
        cfg.devices = 2;
        cfg.codec = CodecChoice::Named("slacc".into());
        cfg.alpha = schedule;
        let report = common::run(cfg, &format!("fig4 alpha={name}"));
        let ttt = report
            .metrics
            .time_to_accuracy(0.55)
            .map_or("-".to_string(), |t| format!("{t:.1}"));
        table.row(vec![
            name.to_string(),
            format!("{:.2}", report.final_accuracy * 100.0),
            format!("{:.2}", report.best_accuracy * 100.0),
            format!("{:.1}", report.total_sim_time_s),
            ttt,
        ]);
        let curve: Vec<(f64, f64)> = report
            .metrics
            .accuracy_curve()
            .into_iter()
            .map(|(r, a)| (r as f64, a))
            .collect();
        table.series(&format!("fig4b_alpha_{name}_acc_vs_round"), &curve);
    }
    table.finish();
}
