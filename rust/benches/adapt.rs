//! Adaptive renegotiation — controller-vs-static communication bench.
//!
//!     cargo bench --bench adapt            # full sweep, rewrites BENCH_adapt.json
//!     cargo bench --bench adapt -- --smoke # seconds-fast CI smoke
//!
//! Two sessions per fleet size through the real scheduler + server runtime
//! over loopback (engine-free, runs anywhere):
//!
//! * **static** — `uniform8` on both data streams for the whole session
//!   (the fidelity a fixed negotiation would keep paying for);
//! * **ladder** — the same session under
//!   `--adapt ladder:uniform8,uniform4;cooldown=2`: the entropy-budget
//!   controller sees a stable activation distribution and steps the fleet
//!   down to `uniform4` mid-session via the SpecUpdate handshake.
//!
//! Uniform codecs never touch the entropy gauges, so the windowed variance
//! the controller reads is exactly zero and the rung walk is deterministic:
//! the step decided at the close of round 1 activates at round 3
//! (`ACTIVATION_LEAD`). Rounds 0..3 of both sessions are therefore
//! bit-identical — asserted — and every later round ships half-width
//! uplink payloads.
//!
//! Headline metric: cumulative uplink bytes until the session first reaches
//! the target loss (the worse of the two sessions' best losses, so both
//! crossings exist). The full sweep asserts the controller session gets
//! there on fewer bytes; CI smoke only asserts the structural facts
//! (transition round, prefix parity, total-byte ordering) — loss-crossing
//! margins are left to the full run.
//!
//! Results land in `BENCH_adapt.json` (committed) via the shared recorder
//! in `benches/common.rs`, so the repo keeps a perf trajectory.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use slacc::adapt::ACTIVATION_LEAD;
use slacc::bench::Table;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::metrics::TrainReport;
use slacc::transport::server::run_mock_loopback;
use slacc::util::json::Json;

/// The controller steps after `cooldown` closed rounds; with cooldown=2 the
/// decision lands at the close of round 1 and activates at 1 + LEAD.
const COOLDOWN: usize = 2;
const TRANSITION_ROUND: usize = 1 + ACTIVATION_LEAD;

fn bench_cfg(devices: usize, rounds: usize, adapt: Option<&str>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = (devices * 16).max(256);
    cfg.test_n = 16;
    cfg.eval_every = rounds.max(1); // one eval at the end
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("uniform8".into());
    cfg.adapt = adapt.map(str::to_string);
    // bandwidth-skewed fleet: the last device models a 4x-slower link.
    // Under the in-order schedule this skews only the simulated network
    // time, never the numerics — which is what keeps the static/ladder
    // pre-activation prefixes bit-comparable.
    let mut speeds = vec![1.0; devices];
    speeds[devices - 1] = 0.25;
    cfg.device_speeds = speeds;
    cfg
}

fn run_session(devices: usize, rounds: usize, adapt: Option<&str>) -> (TrainReport, f64) {
    let cfg = bench_cfg(devices, rounds, adapt);
    let t0 = Instant::now();
    let report = run_mock_loopback(&cfg)
        .unwrap_or_else(|e| panic!("fleet {devices} adapt {adapt:?}: {e}"));
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(report.rounds_run, rounds, "fleet {devices} adapt {adapt:?}");
    assert!(
        report.metrics.records.iter().all(|r| r.loss.is_finite()),
        "fleet {devices} adapt {adapt:?}: non-finite loss"
    );
    (report, wall)
}

fn total_bytes_up(r: &TrainReport) -> usize {
    r.metrics.records.iter().map(|rec| rec.bytes_up).sum()
}

fn best_loss(r: &TrainReport) -> f64 {
    r.metrics.records.iter().map(|rec| rec.loss).fold(f64::INFINITY, f64::min)
}

/// Cumulative uplink bytes up to and including the first round whose loss
/// reaches `target`; `None` if the session never gets there.
fn bytes_to_target(r: &TrainReport, target: f64) -> Option<usize> {
    let mut cum = 0usize;
    for rec in &r.metrics.records {
        cum += rec.bytes_up;
        if rec.loss <= target {
            return Some(cum);
        }
    }
    None
}

fn sweep(fleets: &[usize], rounds: usize, full: bool) {
    let ladder_directive =
        format!("ladder:uniform8,uniform4;cooldown={COOLDOWN}");
    let mut table = Table::new(
        "adapt: entropy-budget ladder vs static uniform8 (mock fleet)",
        &["devices", "session", "transition", "bytes_up", "best_loss",
          "bytes_to_target", "wall_s"],
    );
    let mut rec = common::BenchRecorder::new("adapt");
    for &devices in fleets {
        let (stat, stat_wall) = run_session(devices, rounds, None);
        let (lad, lad_wall) = run_session(devices, rounds, Some(&ladder_directive));

        // the rung walk is deterministic: one step, activating at the
        // agreed boundary, and the pre-activation prefix is bit-identical
        // to the static session
        for (r, (s, l)) in stat.metrics.records.iter().zip(&lad.metrics.records).enumerate() {
            assert_eq!(s.spec, "uplink=uniform8 downlink=uniform8 sync=identity");
            if r < TRANSITION_ROUND {
                assert_eq!(l.spec, s.spec, "fleet {devices} round {r}");
                assert_eq!(s.loss.to_bits(), l.loss.to_bits(), "fleet {devices} round {r}");
                assert_eq!(s.bytes_up, l.bytes_up, "fleet {devices} round {r}");
                assert_eq!(s.bytes_down, l.bytes_down, "fleet {devices} round {r}");
            } else {
                assert_eq!(
                    l.spec, "uplink=uniform4 downlink=uniform4 sync=identity",
                    "fleet {devices} round {r}: transition did not hold"
                );
                assert!(
                    l.bytes_up < s.bytes_up,
                    "fleet {devices} round {r}: half-width payloads must be smaller"
                );
            }
        }
        let stat_total = total_bytes_up(&stat);
        let lad_total = total_bytes_up(&lad);
        assert!(
            lad_total < stat_total,
            "fleet {devices}: ladder session must ship fewer uplink bytes \
             ({lad_total} vs {stat_total})"
        );

        // target = the worse of the two best losses, so both sessions have
        // a crossing round and the byte counts are comparable
        let target = best_loss(&stat).max(best_loss(&lad));
        let stat_btt = bytes_to_target(&stat, target).expect("static never hit its own best");
        let lad_btt = bytes_to_target(&lad, target).expect("ladder never hit its own best");
        if full {
            // the acceptance claim: the controller reaches the target loss
            // on fewer uplink bytes than the static negotiation
            assert!(
                lad_btt < stat_btt,
                "fleet {devices}: ladder needed {lad_btt} bytes to reach \
                 loss {target:.6}, static needed {stat_btt}"
            );
        }

        for (session, report, transition, btt, wall) in [
            ("static-uniform8", &stat, None, stat_btt, stat_wall),
            ("ladder-uniform4", &lad, Some(TRANSITION_ROUND), lad_btt, lad_wall),
        ] {
            table.row(vec![
                devices.to_string(),
                session.to_string(),
                transition.map_or("-".to_string(), |t| t.to_string()),
                total_bytes_up(report).to_string(),
                format!("{:.6}", best_loss(report)),
                btt.to_string(),
                format!("{wall:.4}"),
            ]);
            rec.row(vec![
                ("devices", Json::Num(devices as f64)),
                ("session", Json::Str(session.to_string())),
                ("rounds", Json::Num(rounds as f64)),
                (
                    "transition_round",
                    transition.map_or(Json::Null, |t| Json::Num(t as f64)),
                ),
                ("bytes_up_total", Json::Num(total_bytes_up(report) as f64)),
                ("best_loss", Json::Num(best_loss(report))),
                ("target_loss", Json::Num(target)),
                ("bytes_to_target", Json::Num(btt as f64)),
                ("wall_s", Json::Num(wall)),
            ]);
        }
    }
    table.finish();
    if full {
        // only the full sweep updates the committed perf-trajectory file;
        // the CI smoke subset must not clobber it with its reduced grid
        rec.write();
    } else {
        println!("[smoke mode: BENCH_adapt.json left untouched]");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("[adapt bench: smoke mode]");
        // CI gate: panics / transition drift / prefix-parity drift fail the
        // job; the bytes-to-target ordering is asserted only in the full
        // sweep (its margin depends on the loss trajectory, not structure)
        sweep(&[3], 6, false);
    } else {
        sweep(&[4, 16], 12, true);
    }
}
