//! Cross-device batched server compute — dispatch-amortization bench.
//!
//!     cargo bench --bench batching            # full sweep
//!     cargo bench --bench batching -- --smoke # seconds-fast CI smoke
//!
//! Fleet sizes × `--batch-window` settings through the real arrival-order
//! scheduler + server runtime over loopback (engine-free, runs anywhere).
//! The mock compute burns a modeled PJRT-boundary cost once per
//! `server_step` *dispatch* — the latency a real engine pays per
//! `execute()` call — so the wall-clock numbers isolate exactly what
//! batching amortizes. Batched semantics are the sequential chain, so
//! every configuration is also checked for bit-identical losses and wire
//! bytes against its `--batch-window 1` baseline (the mock model is
//! arrival-order-deterministic at zero delay).
//!
//! Results land in `BENCH_batching.json` (committed) via the shared
//! recorder in `benches/common.rs`, so the repo keeps a perf trajectory.

#[path = "common.rs"]
mod common;

use std::time::{Duration, Instant};

use slacc::bench::Table;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::TrainReport;
use slacc::sched::Policy;
use slacc::transport::server::run_mock_loopback_shimmed;
use slacc::util::json::Json;

/// Modeled cost of one PJRT-boundary crossing. 200 us is mid-range for a
/// CPU PJRT dispatch of this model's server_step (see
/// `benches/microbench.rs` for measured numbers when artifacts exist).
const DISPATCH_COST: Duration = Duration::from_micros(200);

fn bench_cfg(devices: usize, rounds: usize, window: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = (devices * 16).max(256);
    cfg.test_n = 16;
    cfg.eval_every = rounds.max(1); // one eval at the end
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg.schedule = Policy::arrival();
    cfg.batch_window = window;
    cfg
}

fn run_session(devices: usize, rounds: usize, window: usize) -> (TrainReport, f64) {
    let cfg = bench_cfg(devices, rounds, window);
    let delays = vec![0.0; devices];
    let t0 = Instant::now();
    let (report, _) = run_mock_loopback_shimmed(&cfg, &delays, 0, DISPATCH_COST)
        .unwrap_or_else(|e| panic!("fleet {devices} window {window}: {e}"));
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(report.rounds_run, rounds, "fleet {devices} window {window}");
    assert!(
        report.metrics.records.iter().all(|r| r.loss.is_finite()),
        "fleet {devices} window {window}: non-finite loss"
    );
    (report, wall)
}

/// Bit-level parity of a batched run against its window-1 baseline:
/// batching must change dispatch count and nothing else.
fn assert_parity(base: &TrainReport, batched: &TrainReport, devices: usize, window: usize) {
    assert_eq!(base.metrics.len(), batched.metrics.len());
    for (a, b) in base.metrics.records.iter().zip(&batched.metrics.records) {
        let ctx = format!("fleet {devices} window {window} round {}", a.round);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss drift: {ctx}");
        assert_eq!(a.bytes_up, b.bytes_up, "uplink bytes drift: {ctx}");
        assert_eq!(a.bytes_down, b.bytes_down, "downlink bytes drift: {ctx}");
        assert_eq!(a.bytes_sync, b.bytes_sync, "sync bytes drift: {ctx}");
        assert_eq!(a.accuracy, b.accuracy, "accuracy drift: {ctx}");
    }
    assert_eq!(base.server_steps, batched.server_steps);
}

fn sweep(fleets: &[usize], windows: &[usize], rounds: usize, full: bool) {
    let mut table = Table::new(
        "batching: server dispatch amortization (mock fleet, modeled 200us dispatch)",
        &["devices", "window", "steps", "dispatches", "steps_per_disp", "wall_s", "speedup"],
    );
    let mut rec = common::BenchRecorder::new("batching");
    assert_eq!(windows.first(), Some(&1), "sweep needs the window-1 baseline first");
    for &devices in fleets {
        let mut base: Option<(TrainReport, f64)> = None;
        for &window in windows {
            let (report, wall) = run_session(devices, rounds, window);
            if let Some((b, _)) = &base {
                assert_parity(b, &report, devices, window);
            } else {
                assert_eq!(
                    report.server_dispatches, report.server_steps,
                    "window 1 must dispatch per device"
                );
            }
            let base_wall = base.as_ref().map_or(wall, |&(_, w)| w);
            if base.is_none() {
                base = Some((report.clone(), wall));
            }
            if window > 1 {
                assert!(
                    report.server_dispatches < report.server_steps,
                    "fleet {devices} window {window}: batching never amortized a dispatch"
                );
            }
            let speedup = base_wall / wall.max(1e-12);
            if full && devices >= 16 && window >= 4 {
                assert!(
                    speedup > 1.0,
                    "fleet {devices} window {window}: batched dispatch did not beat \
                     per-device dispatch ({wall:.4}s vs {base_wall:.4}s)"
                );
            }
            let per_disp =
                report.server_steps as f64 / report.server_dispatches.max(1) as f64;
            table.row(vec![
                devices.to_string(),
                window.to_string(),
                report.server_steps.to_string(),
                report.server_dispatches.to_string(),
                format!("{per_disp:.2}"),
                format!("{wall:.4}"),
                format!("{speedup:.2}"),
            ]);
            rec.row(vec![
                ("devices", Json::Num(devices as f64)),
                ("window", Json::Num(window as f64)),
                ("rounds", Json::Num(rounds as f64)),
                ("server_steps", Json::Num(report.server_steps as f64)),
                ("server_dispatches", Json::Num(report.server_dispatches as f64)),
                ("steps_per_dispatch", Json::Num(per_disp)),
                ("dispatch_cost_us", Json::Num(DISPATCH_COST.as_micros() as f64)),
                ("wall_s", Json::Num(wall)),
                ("speedup_vs_window1", Json::Num(speedup)),
            ]);
        }
    }
    table.finish();
    if full {
        // only the full sweep updates the committed perf-trajectory file;
        // the CI smoke subset must not clobber it with its reduced grid
        rec.write();
    } else {
        println!("[smoke mode: BENCH_batching.json left untouched]");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("[batching bench: smoke mode]");
        // CI gate: panics / shape mismatches / parity drift fail the job;
        // the wall-clock ordering is asserted only in the full sweep
        // (shared CI runners are too noisy for timing assertions)
        sweep(&[4, 16], &[1, 4], 2, false);
    } else {
        sweep(&[4, 16, 64], &[1, 4, 8], 6, true);
    }
}
