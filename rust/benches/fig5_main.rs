//! Fig. 5 — the headline result: SL-ACC vs PowerQuant-SL vs RandTopk-SL vs
//! SplitFC on both datasets under IID and Dirichlet(0.5) non-IID, reported
//! as test accuracy vs *simulated wall-clock time* (the paper's axes) plus
//! final accuracy and communication volume.
//!
//! Expected shape (paper): SL-ACC reaches any target accuracy first and
//! ends highest; SplitFC > PowerQuant-SL > RandTopk-SL.
//!
//!     cargo bench --bench fig5_main
//!
//! Scale with SLACC_BENCH_ROUNDS / SLACC_BENCH_TRAIN_N (see common.rs).

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::config::CodecChoice;
use slacc::data::partition::Partition;

const CODECS: &[&str] = &["slacc", "powerquant", "randtopk", "splitfc"];

fn main() {
    let datasets = ["ham", "mnist"];
    for d in datasets {
        common::require_artifacts(d);
    }

    for dataset in datasets {
        for (setting, part) in [
            ("IID", Partition::Iid),
            ("non-IID", Partition::Dirichlet { beta: 0.5 }),
        ] {
            let mut table = Table::new(
                &format!("fig5: {dataset} {setting}"),
                &["codec", "final_acc%", "best_acc%", "MB_total", "sim_time_s",
                  "time_to_50%_s"],
            );
            for codec in CODECS {
                let mut cfg = common::base_cfg(dataset);
                cfg.partition = part;
                cfg.codec = CodecChoice::Named(codec.to_string());
                let report =
                    common::run(cfg, &format!("fig5 {dataset} {setting} {codec}"));
                let ttt = report
                    .metrics
                    .time_to_accuracy(0.5)
                    .map_or("-".to_string(), |t| format!("{t:.1}"));
                table.row(vec![
                    codec.to_string(),
                    format!("{:.2}", report.final_accuracy * 100.0),
                    format!("{:.2}", report.best_accuracy * 100.0),
                    format!(
                        "{:.2}",
                        (report.total_bytes_up + report.total_bytes_down) as f64 / 1e6
                    ),
                    format!("{:.1}", report.total_sim_time_s),
                    ttt,
                ]);
                table.series(
                    &format!("fig5_{dataset}_{setting}_{codec}_acc_vs_time"),
                    &report.metrics.accuracy_vs_time(),
                );
            }
            table.finish();
        }
    }
}
