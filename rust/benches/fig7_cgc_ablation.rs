//! Fig. 7 — CGC ablation: SL-ACC's grouped adaptive bit allocation vs
//! fixed-bit PowerQuant and EasyQuant (uniform allocation across channels),
//! on synth-HAM under IID and non-IID. Also includes the verbatim Eq. 6
//! bit-allocation variant (`slacc-paper-eq6`) to quantify the floor-rule
//! degeneracy documented in DESIGN.md.
//!
//! Paper shape: CGC (SL-ACC) > PowerQuant > EasyQuant at matched/ lower
//! communication volume.
//!
//!     cargo bench --bench fig7_cgc_ablation

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::config::CodecChoice;
use slacc::data::partition::Partition;

const CODECS: &[&str] = &["slacc", "slacc-paper-eq6", "powerquant", "easyquant"];

fn main() {
    common::require_artifacts("ham");

    for (setting, part) in [
        ("IID", Partition::Iid),
        ("non-IID", Partition::Dirichlet { beta: 0.5 }),
    ] {
        let mut table = Table::new(
            &format!("fig7: CGC ablation (synth-HAM, {setting})"),
            &["quantizer", "final_acc%", "best_acc%", "MB_total", "sim_time_s"],
        );
        for codec in CODECS {
            let mut cfg = common::base_cfg("ham");
            cfg.partition = part;
            cfg.codec = CodecChoice::Named(codec.to_string());
            let report = common::run(cfg, &format!("fig7 {setting} {codec}"));
            table.row(vec![
                codec.to_string(),
                format!("{:.2}", report.final_accuracy * 100.0),
                format!("{:.2}", report.best_accuracy * 100.0),
                format!(
                    "{:.2}",
                    (report.total_bytes_up + report.total_bytes_down) as f64 / 1e6
                ),
                format!("{:.1}", report.total_sim_time_s),
            ]);
            table.series(
                &format!("fig7_{setting}_{codec}_acc_vs_time"),
                &report.metrics.accuracy_vs_time(),
            );
        }
        table.finish();
    }
}
