//! Multi-server sharding — coordinator-tier scaling bench.
//!
//!     cargo bench --bench sharding            # full sweep
//!     cargo bench --bench sharding -- --smoke # seconds-fast CI smoke
//!
//! A fixed mock device fleet is partitioned across 1/2/4 shard servers
//! and driven through the *real* stack: per-shard `ServerRuntime`s +
//! device workers on threads, the real `Coordinator` over channel
//! transports, real ShardHello/ShardSync frames and `--sync-codec`
//! packs (`run_sharded_mock` — nothing is stubbed). A second sweep holds
//! the topology at 2 shards and amortizes the cross-shard cadence
//! (`--shard-sync-every` 1/2/4), quantifying the sync-byte/coordination
//! trade the flag exists for.
//!
//! Results land in `BENCH_sharding.json` (committed) via the shared
//! recorder in `benches/common.rs`, so the repo keeps a perf trajectory.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use slacc::bench::Table;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::shard::sim::{run_sharded_mock, ShardedReport};
use slacc::util::json::Json;

fn bench_cfg(devices: usize, shards: usize, sync_every: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = (devices * 16).max(256);
    cfg.test_n = 32;
    cfg.eval_every = rounds.max(1); // one eval at the end
    cfg.lr = 1e-3;
    cfg.seed = 3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg.shards = shards;
    cfg.shard_sync_every = sync_every;
    cfg
}

fn run_cluster(
    devices: usize,
    shards: usize,
    sync_every: usize,
    rounds: usize,
) -> (ShardedReport, f64) {
    let cfg = bench_cfg(devices, shards, sync_every, rounds);
    let t0 = Instant::now();
    let report = run_sharded_mock(&cfg)
        .unwrap_or_else(|e| panic!("{shards} shards, sync-every {sync_every}: {e}"));
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(report.shard_reports.len(), shards);
    for (k, rep) in report.shard_reports.iter().enumerate() {
        assert_eq!(rep.rounds_run, rounds, "shard {k}");
        assert!(
            rep.metrics.records.iter().all(|r| r.loss.is_finite()),
            "shard {k}: non-finite loss"
        );
    }
    if shards > 1 {
        assert_eq!(
            report.coordinator.sync_epochs,
            rounds / sync_every,
            "{shards} shards: wrong sync-epoch count"
        );
        assert!(report.coordinator.bytes_up > 0);
    }
    (report, wall)
}

fn sweep(shard_counts: &[usize], cadences: &[usize], devices: usize, rounds: usize, full: bool) {
    let mut table = Table::new(
        "sharding: coordinator tier over a fixed mock fleet",
        &["shards", "sync_every", "epochs", "sync_KB", "coord_KB", "acc", "wall_s"],
    );
    let mut rec = common::BenchRecorder::new("sharding");
    let mut row = |report: &ShardedReport, shards: usize, sync_every: usize, wall: f64| {
        let sync_kb = report.total_bytes_sync() as f64 / 1e3;
        let coord_b = report.coordinator.bytes_up + report.coordinator.bytes_down;
        let (_, acc) = report.accuracy_range();
        table.row(vec![
            shards.to_string(),
            sync_every.to_string(),
            report.coordinator.sync_epochs.to_string(),
            format!("{sync_kb:.1}"),
            format!("{:.1}", coord_b as f64 / 1e3),
            format!("{acc:.3}"),
            format!("{wall:.3}"),
        ]);
        rec.row(vec![
            ("devices", Json::Num(devices as f64)),
            ("shards", Json::Num(shards as f64)),
            ("sync_every", Json::Num(sync_every as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("sync_epochs", Json::Num(report.coordinator.sync_epochs as f64)),
            ("bytes_sync_total", Json::Num(report.total_bytes_sync() as f64)),
            ("coord_bytes_up", Json::Num(report.coordinator.bytes_up as f64)),
            ("coord_bytes_down", Json::Num(report.coordinator.bytes_down as f64)),
            ("final_accuracy", Json::Num(acc)),
            ("wall_s", Json::Num(wall)),
        ]);
    };

    // shard-count scaling at the default cadence; the 2-shard run doubles
    // as the cadence sweep's sync-every-1 baseline (no duplicate run/row)
    let mut single_acc = None;
    let mut two_shard_sync: Option<usize> = None;
    for &shards in shard_counts {
        let (report, wall) = run_cluster(devices, shards, 1, rounds);
        let (lo, hi) = report.accuracy_range();
        assert_eq!(lo, hi, "{shards} shards: shards must agree after a full merge");
        match single_acc {
            None => single_acc = Some(hi),
            Some(base) => assert!(
                (hi - base).abs() < 0.05,
                "{shards} shards drifted from the single-server accuracy \
                 ({hi} vs {base})"
            ),
        }
        if shards == 2 {
            two_shard_sync = Some(report.total_bytes_sync());
        }
        row(&report, shards, 1, wall);
    }

    // cadence amortization at a fixed 2-shard topology
    let mut prev_sync = two_shard_sync;
    for &sync_every in cadences {
        if sync_every == 1 || rounds % sync_every != 0 {
            continue; // 1 is the shard-count sweep's 2-shard row
        }
        let (report, wall) = run_cluster(devices, 2, sync_every, rounds);
        let total = report.total_bytes_sync();
        if let Some(prev) = prev_sync {
            assert!(
                total < prev,
                "sync-every {sync_every}: amortizing must shrink the sync byte \
                 axis ({total} >= {prev})"
            );
        }
        prev_sync = Some(total);
        row(&report, 2, sync_every, wall);
    }

    table.finish();
    if full {
        // only the full sweep updates the committed perf-trajectory file;
        // the CI smoke subset must not clobber it with its reduced grid
        rec.write();
    } else {
        println!("[smoke mode: BENCH_sharding.json left untouched]");
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    if smoke {
        println!("[sharding bench: smoke mode]");
        // CI gate: cluster completion, sync-epoch counts, byte-axis
        // monotonicity, cross-shard accuracy agreement (wall clock is
        // reported, never asserted — shared runners are noisy)
        sweep(&[1, 2], &[1, 2], 4, 4, false);
    } else {
        sweep(&[1, 2, 4], &[1, 2, 4], 8, common::env_usize("SLACC_BENCH_ROUNDS", 8), true);
    }
}
