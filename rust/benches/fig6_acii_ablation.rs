//! Fig. 6 — ACII ablation: channel selection by blended entropy (ACII) vs
//! highest-STD vs random, on synth-HAM under IID and non-IID.
//!
//! Paper shape: ACII > STD > Random in both convergence speed and final
//! accuracy.
//!
//!     cargo bench --bench fig6_acii_ablation

#[path = "common.rs"]
mod common;

use slacc::bench::Table;
use slacc::codecs::selection::Selection;
use slacc::config::CodecChoice;
use slacc::data::partition::Partition;

fn main() {
    common::require_artifacts("ham");
    let strategies = [
        ("ACII", Selection::EntropyBlended),
        ("STD", Selection::MaxStd),
        ("Random", Selection::Random),
    ];

    for (setting, part) in [
        ("IID", Partition::Iid),
        ("non-IID", Partition::Dirichlet { beta: 0.5 }),
    ] {
        let mut table = Table::new(
            &format!("fig6: ACII ablation (synth-HAM, {setting})"),
            &["selection", "final_acc%", "best_acc%", "mean_loss_tail"],
        );
        for (name, strategy) in strategies {
            let mut cfg = common::base_cfg("ham");
            cfg.devices = 2;
            cfg.partition = part;
            // transmit a quarter of the channels, chosen by the strategy:
            // isolates the selection criterion itself (Fig. 6's question)
            cfg.codec = CodecChoice::Select {
                strategy,
                n_select: 8,
            };
            let report = common::run(cfg, &format!("fig6 {setting} {name}"));
            table.row(vec![
                name.to_string(),
                format!("{:.2}", report.final_accuracy * 100.0),
                format!("{:.2}", report.best_accuracy * 100.0),
                format!("{:.4}", report.metrics.mean_loss_tail(5)),
            ]);
            let curve: Vec<(f64, f64)> = report
                .metrics
                .accuracy_curve()
                .into_iter()
                .map(|(r, a)| (r as f64, a))
                .collect();
            table.series(&format!("fig6_{setting}_{name}_acc_vs_round"), &curve);
        }
        table.finish();
    }
}
