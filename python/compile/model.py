"""L2: the split GN-ResNet model in pure JAX (build-time only).

The paper partitions ResNet-18 after its first three layers: the client-side
sub-model produces the *smashed data* (cut-layer activations), the server
runs the rest. We reproduce that topology as a GroupNorm ResNet (GN-ResNet-8)
so every artifact is a pure function of (params, data) — BatchNorm running
stats would leak mutable state into the AOT interface.

  client : conv3x3(in,32) -> GN -> relu -> ResBlock(32->32, stride 2)
           => smashed data (B, 32, 16, 16) for 32x32 inputs
  server : ResBlock(32->64, s2) -> ResBlock(64->128, s2) -> GAP -> FC(classes)

Four phase functions are AOT-lowered (see aot.py):

  client_fwd  (cp..., x)                -> (acts,)
  server_step (sp..., acts, y, lr)      -> (loss, g_acts, sp'...)
  client_bwd  (cp..., x, g_acts, lr)    -> (cp'...,)
  eval_logits (cp..., sp..., x)         -> (logits,)

All of them take/return *flat* tuples of arrays — the PJRT interface has no
pytrees — with the ordering pinned by client_spec()/server_spec(), which is
also serialized into the manifest so the Rust runtime addresses parameters
by name.

Training semantics match the paper's setup: plain SGD (lr supplied as a
runtime scalar), softmax cross-entropy on integer labels. ``server_step``
fuses forward, backward, the gradient w.r.t. the smashed data (the downlink
payload) and the SGD update into one HLO module; ``client_bwd`` recomputes
the client forward and applies the chain rule with the (decompressed)
upstream gradient.
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration baked into the AOT artifacts."""

    name: str = "ham"
    in_ch: int = 3
    num_classes: int = 7
    batch: int = 32
    img: int = 32
    width: int = 32          # channels at the cut layer
    gn_groups: int = 8

    @property
    def cut_shape(self) -> Tuple[int, int, int, int]:
        """Smashed-data shape (B, C, H, W) after the stride-2 client block."""
        return (self.batch, self.width, self.img // 2, self.img // 2)


HAM_CONFIG = ModelConfig(name="ham", in_ch=3, num_classes=7)
MNIST_CONFIG = ModelConfig(name="mnist", in_ch=1, num_classes=10)

CONFIGS = {c.name: c for c in (HAM_CONFIG, MNIST_CONFIG)}


# --------------------------------------------------------------------------
# Parameter specs — the single source of truth for flat ordering.
# --------------------------------------------------------------------------

def _block_spec(prefix: str, cin: int, cout: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """Residual block params: two 3x3 convs + GN affine + 1x1 projection."""
    return [
        (f"{prefix}.conv1", (cout, cin, 3, 3)),
        (f"{prefix}.gn1.scale", (cout,)),
        (f"{prefix}.gn1.bias", (cout,)),
        (f"{prefix}.conv2", (cout, cout, 3, 3)),
        (f"{prefix}.gn2.scale", (cout,)),
        (f"{prefix}.gn2.bias", (cout,)),
        (f"{prefix}.proj", (cout, cin, 1, 1)),
        (f"{prefix}.gnp.scale", (cout,)),
        (f"{prefix}.gnp.bias", (cout,)),
    ]


def client_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    w = cfg.width
    return [
        ("stem.conv", (w, cfg.in_ch, 3, 3)),
        ("stem.gn.scale", (w,)),
        ("stem.gn.bias", (w,)),
    ] + _block_spec("block1", w, w)


def server_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    w = cfg.width
    return (
        _block_spec("block2", w, 2 * w)
        + _block_spec("block3", 2 * w, 4 * w)
        + [
            ("fc.weight", (4 * w, cfg.num_classes)),
            ("fc.bias", (cfg.num_classes,)),
        ]
    )


def param_count(spec: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for _, shape in spec:
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def init_params(spec: List[Tuple[str, Tuple[int, ...]]], key: jax.Array
                ) -> List[jnp.ndarray]:
    """He-normal init for convs/FC, ones/zeros for GN scale/bias."""
    out = []
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith(".scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".bias"):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name == "fc.weight":
            fan_in = shape[0]
            out.append(jax.random.normal(sub, shape, jnp.float32)
                       * jnp.sqrt(2.0 / fan_in))
        else:  # conv kernels (cout, cin, kh, kw)
            fan_in = shape[1] * shape[2] * shape[3]
            out.append(jax.random.normal(sub, shape, jnp.float32)
                       * jnp.sqrt(2.0 / fan_in))
    return out


def _as_dict(spec, flat) -> Dict[str, jnp.ndarray]:
    assert len(spec) == len(flat), (len(spec), len(flat))
    return {name: arr for (name, _), arr in zip(spec, flat)}


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------

def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """NCHW 'SAME' convolution with OIHW kernels."""
    return lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def group_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """Stateless GroupNorm over NCHW (normalizes within channel groups)."""
    b, c, h, w = x.shape
    g = min(groups, c)
    xg = x.reshape(b, g, c // g, h, w)
    mean = jnp.mean(xg, axis=(2, 3, 4), keepdims=True)
    var = jnp.var(xg, axis=(2, 3, 4), keepdims=True)
    xn = ((xg - mean) * lax.rsqrt(var + eps)).reshape(b, c, h, w)
    return xn * scale[None, :, None, None] + bias[None, :, None, None]


def res_block(x: jnp.ndarray, p: Dict[str, jnp.ndarray], prefix: str,
              stride: int, groups: int) -> jnp.ndarray:
    """Projection residual block: out = relu(main(x) + proj(x))."""
    h = conv2d(x, p[f"{prefix}.conv1"], stride)
    h = group_norm(h, p[f"{prefix}.gn1.scale"], p[f"{prefix}.gn1.bias"], groups)
    h = jax.nn.relu(h)
    h = conv2d(h, p[f"{prefix}.conv2"], 1)
    h = group_norm(h, p[f"{prefix}.gn2.scale"], p[f"{prefix}.gn2.bias"], groups)
    s = conv2d(x, p[f"{prefix}.proj"], stride)
    s = group_norm(s, p[f"{prefix}.gnp.scale"], p[f"{prefix}.gnp.bias"], groups)
    return jax.nn.relu(h + s)


# --------------------------------------------------------------------------
# Sub-model forwards
# --------------------------------------------------------------------------

def client_forward(cfg: ModelConfig, cp: List[jnp.ndarray], x: jnp.ndarray
                   ) -> jnp.ndarray:
    """Client sub-model: (B, in_ch, 32, 32) -> smashed data (B, W, 16, 16)."""
    p = _as_dict(client_spec(cfg), cp)
    h = conv2d(x, p["stem.conv"], 1)
    h = group_norm(h, p["stem.gn.scale"], p["stem.gn.bias"], cfg.gn_groups)
    h = jax.nn.relu(h)
    return res_block(h, p, "block1", 2, cfg.gn_groups)


def server_forward(cfg: ModelConfig, sp: List[jnp.ndarray], acts: jnp.ndarray
                   ) -> jnp.ndarray:
    """Server sub-model: smashed data -> logits (B, num_classes)."""
    p = _as_dict(server_spec(cfg), sp)
    h = res_block(acts, p, "block2", 2, cfg.gn_groups)
    h = res_block(h, p, "block3", 2, cfg.gn_groups)
    h = jnp.mean(h, axis=(2, 3))  # global average pool -> (B, 4W)
    return h @ p["fc.weight"] + p["fc.bias"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# AOT phase functions (flat-tuple interfaces)
# --------------------------------------------------------------------------

def make_client_fwd(cfg: ModelConfig):
    n = len(client_spec(cfg))

    def client_fwd(*args):
        cp, x = list(args[:n]), args[n]
        return (client_forward(cfg, cp, x),)

    return client_fwd


def make_server_step(cfg: ModelConfig):
    """(sp..., acts, y, lr) -> (loss, g_acts, sp'...). Fused fwd+bwd+SGD."""
    n = len(server_spec(cfg))

    def server_step(*args):
        sp = list(args[:n])
        acts, y, lr = args[n], args[n + 1], args[n + 2]

        def loss_fn(sp_in, acts_in):
            return cross_entropy(server_forward(cfg, sp_in, acts_in), y)

        loss, (g_sp, g_acts) = jax.value_and_grad(loss_fn, argnums=(0, 1))(sp, acts)
        new_sp = [p - lr * g for p, g in zip(sp, g_sp)]
        return (loss, g_acts, *new_sp)

    return server_step


def make_client_bwd(cfg: ModelConfig):
    """(cp..., x, g_acts, lr) -> (cp'...,). Recompute fwd, chain rule, SGD."""
    n = len(client_spec(cfg))

    def client_bwd(*args):
        cp = list(args[:n])
        x, g_acts, lr = args[n], args[n + 1], args[n + 2]

        def fwd(cp_in):
            return client_forward(cfg, cp_in, x)

        _, vjp = jax.vjp(fwd, cp)
        (g_cp,) = vjp(g_acts)
        return tuple(p - lr * g for p, g in zip(cp, g_cp))

    return client_bwd


def make_eval_logits(cfg: ModelConfig):
    """(cp..., sp..., x) -> (logits,): full-model inference for test acc."""
    nc = len(client_spec(cfg))
    ns = len(server_spec(cfg))

    def eval_logits(*args):
        cp = list(args[:nc])
        sp = list(args[nc:nc + ns])
        x = args[nc + ns]
        acts = client_forward(cfg, cp, x)
        return (server_forward(cfg, sp, acts),)

    return eval_logits
