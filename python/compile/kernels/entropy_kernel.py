"""L1 Pallas kernel: per-channel Shannon entropy of smashed data (ACII Eq. 1).

This is the per-round compute the paper *adds* to the split-learning data
path — it runs over every activation tensor (uplink) and every cut-layer
gradient tensor (downlink) on every device, every round. It is therefore the
kernel we AOT-compile into ``artifacts/<cfg>/entropy.hlo.txt`` and invoke
from the Rust coordinator.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the input is reshaped to
(C, N) with N = B*H*W; the grid iterates over channels and each program
processes one (1, N) row. At the default config (N = 32*16*16 = 8192) a row
is 32 KiB — comfortably inside VMEM — so the HBM↔VMEM schedule expressed by
the BlockSpec is exactly one read per channel plus one scalar write. The
reductions (min, max, sum) vectorize on the VPU; there is no matmul, so the
kernel is memory-bound and the MXU is idle by design.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _entropy_row_kernel(x_ref, o_ref):
    """One grid step = one channel: (1, N) block -> scalar entropy."""
    row = x_ref[...]  # (1, N) in VMEM
    mn = jnp.min(row)
    mx = jnp.max(row)
    z = (row - mn) / jnp.maximum(mx - mn, EPS)  # min-max normalize to [0,1]
    s = z - jnp.max(z)                          # stable softmax shift
    e = jnp.exp(s)
    total = jnp.sum(e)
    p = e / total
    o_ref[...] = -jnp.sum(p * jnp.log(p)).reshape(1)


@functools.partial(jax.jit, static_argnames=())
def channel_entropy(x2d: jnp.ndarray) -> jnp.ndarray:
    """Per-channel entropy of (C, N) f32 data via the Pallas kernel.

    Returns an (C,) f32 vector H where H[c] is the Shannon entropy (natural
    log) of the softmax distribution over channel c's normalized elements.
    Matches ``ref.channel_entropy_ref`` to float32 round-off.
    """
    c, n = x2d.shape
    return pl.pallas_call(
        _entropy_row_kernel,
        out_shape=jax.ShapeDtypeStruct((c,), jnp.float32),
        grid=(c,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x2d.astype(jnp.float32))


def channel_entropy_nchw(acts: jnp.ndarray) -> jnp.ndarray:
    """Entropy of NCHW activations: channel c pools its N = B*H*W elements.

    This is the entry point AOT-lowered for the Rust coordinator; the
    transpose/reshape fuses into the surrounding HLO.
    """
    b, c, h, w = acts.shape
    x2d = jnp.transpose(acts, (1, 0, 2, 3)).reshape(c, b * h * w)
    return channel_entropy(x2d)
