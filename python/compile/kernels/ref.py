"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground-truth implementations of the two SL-ACC hot-spot
computations:

* ``channel_entropy_ref`` — ACII instantaneous entropy (paper Eq. 1): each
  channel is min-max normalized to [0, 1], converted to a probability
  distribution with a softmax over its N = B*H*W elements, and reduced to
  Shannon entropy H_c = -sum_i p_i log p_i (natural log).
* ``qdq_ref`` — CGC linear quantize-dequantize (paper Eq. 7) with
  round-half-away-from-zero, applied per channel with externally supplied
  [qmin, qmax] boundaries and integer level counts (2^b - 1).

The Pallas kernels in ``entropy_kernel.py`` / ``qdq_kernel.py`` must match
these to ~1e-5; the Rust quantizer (rust/src/quant/linear.rs) implements the
same rounding so wire bytes and the in-graph fake-quant path agree exactly.
"""

import jax.numpy as jnp

EPS = 1e-8


def channel_entropy_ref(x2d: jnp.ndarray) -> jnp.ndarray:
    """Per-channel Shannon entropy of (C, N) smashed data. Returns (C,) f32.

    Pipeline per channel c (paper Sec. II-B):
      1. min-max normalize the N elements to [0, 1]
      2. softmax -> probability distribution p_c(i)
      3. H_c = -sum_i p_c(i) * log p_c(i)
    """
    x2d = x2d.astype(jnp.float32)
    mn = jnp.min(x2d, axis=1, keepdims=True)
    mx = jnp.max(x2d, axis=1, keepdims=True)
    z = (x2d - mn) / jnp.maximum(mx - mn, EPS)
    # stable softmax over the channel's elements; z in [0,1] so the max
    # subtraction is tiny but keeps bit-parity with the kernel.
    s = z - jnp.max(z, axis=1, keepdims=True)
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=1, keepdims=True)
    return -jnp.sum(p * jnp.log(p), axis=1)


def round_half_away(t: jnp.ndarray) -> jnp.ndarray:
    """Round to nearest with halves away from zero (paper Eq. 7 footnote).

    Inputs on the QDQ path are always >= 0 (t = (x - qmin)/scale), but the
    sign-symmetric form is kept so the oracle is total.
    """
    return jnp.sign(t) * jnp.floor(jnp.abs(t) + 0.5)


def qdq_ref(x2d: jnp.ndarray, qmin: jnp.ndarray, qmax: jnp.ndarray,
            levels: jnp.ndarray) -> jnp.ndarray:
    """Per-channel linear fake-quantization of (C, N) data.

    qmin/qmax/levels are (C, 1) f32; ``levels`` is 2^b - 1 for a b-bit code.
    Dequantized value = qmin + code * scale, scale = (qmax - qmin)/levels.
    Degenerate channels (qmax == qmin) collapse to qmin, matching the Rust
    quantizer's flat-channel special case.
    """
    x2d = x2d.astype(jnp.float32)
    rng = qmax - qmin
    scale = jnp.maximum(rng, EPS) / levels
    xc = jnp.clip(x2d, qmin, qmax)
    code = round_half_away((xc - qmin) / scale)
    xhat = qmin + code * scale
    return jnp.where(rng > EPS, xhat, qmin)
