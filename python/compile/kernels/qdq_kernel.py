"""L1 Pallas kernel: fused per-channel linear quantize-dequantize (CGC Eq. 7).

The Rust coordinator performs the *real* quantization (bit-packing actual
wire bytes in rust/src/quant/). This kernel implements the numerically
identical fake-quant x -> dequant(quant(x)) as an in-graph operation, used

* to parity-test the Rust quantizer against JAX (same rounding rule,
  round-half-away-from-zero),
* as the AOT artifact ``qdq.hlo.txt`` for the optional in-graph compression
  path (server-side simulation of the channel without host round-trips),
* as the L1 micro-bench subject.

Layout mirrors the entropy kernel: (C, N) rows, one channel per grid step,
per-channel parameters arriving as (C, 1) operands so each block sees its
own scalars. Elementwise VPU work, one HBM read + one write per element.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-8


def _qdq_row_kernel(x_ref, qmin_ref, qmax_ref, lv_ref, o_ref):
    row = x_ref[...]          # (1, N)
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    levels = lv_ref[0, 0]
    rng = qmax - qmin
    scale = jnp.maximum(rng, EPS) / levels
    xc = jnp.clip(row, qmin, qmax)
    t = (xc - qmin) / scale
    code = jnp.floor(t + 0.5)  # t >= 0, so this IS round-half-away
    xhat = qmin + code * scale
    o_ref[...] = jnp.where(rng > EPS, xhat, qmin)


@functools.partial(jax.jit, static_argnames=())
def qdq(x2d: jnp.ndarray, qmin: jnp.ndarray, qmax: jnp.ndarray,
        levels: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantize (C, N) f32 data with per-channel [qmin, qmax] and level
    counts (2^b - 1). All parameter arrays are (C, 1) f32.
    """
    c, n = x2d.shape
    spec_param = pl.BlockSpec((1, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _qdq_row_kernel,
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            spec_param, spec_param, spec_param,
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x2d.astype(jnp.float32), qmin, qmax, levels)


def qdq_nchw(acts: jnp.ndarray, qmin: jnp.ndarray, qmax: jnp.ndarray,
             levels: jnp.ndarray) -> jnp.ndarray:
    """NCHW wrapper: per-channel fake-quant of (B, C, H, W) activations."""
    b, c, h, w = acts.shape
    x2d = jnp.transpose(acts, (1, 0, 2, 3)).reshape(c, b * h * w)
    y2d = qdq(x2d, qmin, qmax, levels)
    return jnp.transpose(y2d.reshape(c, b, h, w), (1, 0, 2, 3))
