"""AOT pipeline: lower every phase function to HLO *text* + manifest.

This is the single point where Python runs — ``make artifacts`` invokes it
once per model config; afterwards the Rust coordinator is self-contained.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs, per config (artifacts/<name>/):
  client_fwd.hlo.txt   server_step.hlo.txt   client_bwd.hlo.txt
  eval_logits.hlo.txt  entropy.hlo.txt       qdq.hlo.txt
  client_init.bin      server_init.bin       (raw little-endian f32)
  manifest.json        (shapes/dtypes of every artifact's I/O, param specs)

The manifest is the contract with rust/src/runtime/artifacts.rs — any change
to its schema must be mirrored there.
"""

import argparse
import hashlib
import json
import os
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels import entropy_kernel, qdq_kernel

SCHEMA_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _io_entry(name: str, arr) -> dict:
    return {"name": name, "dims": list(arr.shape), "dtype": _dtype_tag(arr.dtype)}


def lower_fn(fn, arg_specs: List[Tuple[str, jax.ShapeDtypeStruct]],
             out_names: List[str], out_path: str) -> dict:
    """Lower ``fn`` at the given shapes, write HLO text, return manifest entry."""
    specs = [s for _, s in arg_specs]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *specs)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    assert len(out_names) == len(outs), (out_names, len(outs))
    return {
        "file": os.path.basename(out_path),
        "inputs": [_io_entry(n, s) for n, s in arg_specs],
        "outputs": [_io_entry(n, s) for n, s in zip(out_names, outs)],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_config(cfg: M.ModelConfig, out_root: str, seed: int) -> None:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)

    cspec = M.client_spec(cfg)
    sspec = M.server_spec(cfg)
    b, c, h, w = cfg.cut_shape
    n_elem = b * h * w

    cp_args = [(name, _sds(shape)) for name, shape in cspec]
    sp_args = [(name, _sds(shape)) for name, shape in sspec]
    x_arg = ("x", _sds((cfg.batch, cfg.in_ch, cfg.img, cfg.img)))
    acts_arg = ("acts", _sds((b, c, h, w)))
    y_arg = ("y", _sds((cfg.batch,), jnp.int32))
    lr_arg = ("lr", _sds((), jnp.float32))

    artifacts = {}

    artifacts["client_fwd"] = lower_fn(
        M.make_client_fwd(cfg), cp_args + [x_arg], ["acts"],
        os.path.join(out_dir, "client_fwd.hlo.txt"))

    artifacts["server_step"] = lower_fn(
        M.make_server_step(cfg), sp_args + [acts_arg, y_arg, lr_arg],
        ["loss", "g_acts"] + [n for n, _ in sspec],
        os.path.join(out_dir, "server_step.hlo.txt"))

    artifacts["client_bwd"] = lower_fn(
        M.make_client_bwd(cfg), cp_args + [x_arg, ("g_acts", _sds((b, c, h, w))), lr_arg],
        [n for n, _ in cspec],
        os.path.join(out_dir, "client_bwd.hlo.txt"))

    artifacts["eval_logits"] = lower_fn(
        M.make_eval_logits(cfg), cp_args + sp_args + [x_arg], ["logits"],
        os.path.join(out_dir, "eval_logits.hlo.txt"))

    # L1 Pallas kernels, lowered standalone so the Rust coordinator can call
    # them on raw smashed data each round.
    artifacts["entropy"] = lower_fn(
        entropy_kernel.channel_entropy_nchw, [acts_arg], ["entropy"],
        os.path.join(out_dir, "entropy.hlo.txt"))

    artifacts["qdq"] = lower_fn(
        qdq_kernel.qdq_nchw,
        [acts_arg,
         ("qmin", _sds((c, 1))), ("qmax", _sds((c, 1))), ("levels", _sds((c, 1)))],
        ["acts_hat"],
        os.path.join(out_dir, "qdq.hlo.txt"))

    # Deterministic initial parameters, raw little-endian f32 blobs.
    key = jax.random.PRNGKey(seed)
    kc, ks = jax.random.split(key)
    cinit = M.init_params(cspec, kc)
    sinit = M.init_params(sspec, ks)

    def dump(path, arrs):
        with open(path, "wb") as f:
            for a in arrs:
                f.write(np.asarray(a, dtype="<f4").tobytes())

    dump(os.path.join(out_dir, "client_init.bin"), cinit)
    dump(os.path.join(out_dir, "server_init.bin"), sinit)

    def spec_json(spec):
        out, off = [], 0
        for name, shape in spec:
            size = int(np.prod(shape))
            out.append({"name": name, "dims": list(shape),
                        "offset": off, "size": size})
            off += size
        return out

    manifest = {
        "schema": SCHEMA_VERSION,
        "config": {
            "name": cfg.name, "in_ch": cfg.in_ch, "classes": cfg.num_classes,
            "batch": cfg.batch, "img": cfg.img,
            "cut": {"b": b, "c": c, "h": h, "w": w, "n_per_channel": n_elem},
            "gn_groups": cfg.gn_groups, "seed": seed,
        },
        "client_params": spec_json(cspec),
        "server_params": spec_json(sspec),
        "client_param_count": M.param_count(cspec),
        "server_param_count": M.param_count(sspec),
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    total = M.param_count(cspec) + M.param_count(sspec)
    print(f"[aot] {cfg.name}: {len(artifacts)} artifacts, "
          f"{total:,} params ({M.param_count(cspec):,} client / "
          f"{M.param_count(sspec):,} server) -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output root dir")
    ap.add_argument("--configs", default="ham,mnist",
                    help="comma-separated config names (ham,mnist)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    for name in args.configs.split(","):
        base = M.CONFIGS[name.strip()]
        cfg = M.ModelConfig(name=base.name, in_ch=base.in_ch,
                            num_classes=base.num_classes, batch=args.batch,
                            img=base.img, width=base.width,
                            gn_groups=base.gn_groups)
        build_config(cfg, args.out, args.seed)


if __name__ == "__main__":
    main()
