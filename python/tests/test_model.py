"""L2 correctness: split model shapes, gradient flow, split/full parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(name="ham", batch=4)


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(0)
    kc, ks = jax.random.split(key)
    cp = M.init_params(M.client_spec(CFG), kc)
    sp = M.init_params(M.server_spec(CFG), ks)
    return cp, sp


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(0)
    x = jnp.array(rng.randn(4, 3, 32, 32), jnp.float32)
    y = jnp.array([0, 1, 2, 3], jnp.int32)
    return x, y


class TestShapes:
    def test_cut_shape(self, params, batch):
        cp, _ = params
        acts = M.client_forward(CFG, cp, batch[0])
        assert acts.shape == CFG.cut_shape == (4, 32, 16, 16)

    def test_logits_shape(self, params, batch):
        cp, sp = params
        acts = M.client_forward(CFG, cp, batch[0])
        logits = M.server_forward(CFG, sp, acts)
        assert logits.shape == (4, 7)

    def test_mnist_config_shapes(self):
        cfg = M.ModelConfig(name="mnist", in_ch=1, num_classes=10, batch=2)
        cp = M.init_params(M.client_spec(cfg), jax.random.PRNGKey(1))
        sp = M.init_params(M.server_spec(cfg), jax.random.PRNGKey(2))
        x = jnp.zeros((2, 1, 32, 32), jnp.float32)
        logits = M.server_forward(cfg, sp, M.client_forward(cfg, cp, x))
        assert logits.shape == (2, 10)

    def test_param_counts_match_spec(self, params):
        cp, sp = params
        assert sum(int(np.prod(p.shape)) for p in cp) == \
            M.param_count(M.client_spec(CFG))
        assert sum(int(np.prod(p.shape)) for p in sp) == \
            M.param_count(M.server_spec(CFG))


class TestServerStep:
    def test_outputs(self, params, batch):
        _, sp = params
        cp, _ = params
        acts = M.client_forward(CFG, cp, batch[0])
        out = M.make_server_step(CFG)(*sp, acts, batch[1], jnp.float32(0.01))
        assert len(out) == 2 + len(sp)
        loss, g_acts = out[0], out[1]
        assert loss.shape == ()
        assert float(loss) > 0
        assert g_acts.shape == acts.shape

    def test_sgd_moves_params(self, params, batch):
        cp, sp = params
        acts = M.client_forward(CFG, cp, batch[0])
        out = M.make_server_step(CFG)(*sp, acts, batch[1], jnp.float32(0.1))
        new_sp = out[2:]
        deltas = [float(jnp.abs(a - b).max()) for a, b in zip(sp, new_sp)]
        assert max(deltas) > 0.0

    def test_zero_lr_freezes_params(self, params, batch):
        cp, sp = params
        acts = M.client_forward(CFG, cp, batch[0])
        out = M.make_server_step(CFG)(*sp, acts, batch[1], jnp.float32(0.0))
        for a, b in zip(sp, out[2:]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_loss_decreases_over_steps(self, params, batch):
        """A few SGD steps on a fixed batch must reduce the loss."""
        cp, sp = params
        x, y = batch
        acts = M.client_forward(CFG, cp, x)
        step = jax.jit(M.make_server_step(CFG))
        sp_cur = list(sp)
        losses = []
        for _ in range(8):
            out = step(*sp_cur, acts, y, jnp.float32(0.05))
            losses.append(float(out[0]))
            sp_cur = list(out[2:])
        assert losses[-1] < losses[0]


class TestClientBwd:
    def test_chain_rule_matches_end_to_end(self, params, batch):
        """client_bwd(g_acts from server) == grad of the composed loss."""
        cp, sp = params
        x, y = batch
        lr = 0.01

        # end-to-end gradient
        def full_loss(cp_in):
            acts = M.client_forward(CFG, cp_in, x)
            return M.cross_entropy(M.server_forward(CFG, sp, acts), y)

        g_full = jax.grad(full_loss)(cp)
        expected = [p - lr * g for p, g in zip(cp, g_full)]

        # split pipeline
        acts = M.client_forward(CFG, cp, x)
        out = M.make_server_step(CFG)(*sp, acts, y, jnp.float32(0.0))
        g_acts = out[1]
        got = M.make_client_bwd(CFG)(*cp, x, g_acts, jnp.float32(lr))

        for e, g in zip(expected, got):
            np.testing.assert_allclose(np.asarray(e), np.asarray(g),
                                       rtol=1e-4, atol=1e-5)

    def test_zero_gradient_noop(self, params, batch):
        cp, _ = params
        g0 = jnp.zeros(CFG.cut_shape, jnp.float32)
        got = M.make_client_bwd(CFG)(*cp, batch[0], g0, jnp.float32(1.0))
        for a, b in zip(cp, got):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


class TestEvalAndParity:
    def test_eval_matches_split_pipeline(self, params, batch):
        cp, sp = params
        logits_eval = M.make_eval_logits(CFG)(*cp, *sp, batch[0])[0]
        acts = M.client_forward(CFG, cp, batch[0])
        logits_split = M.server_forward(CFG, sp, acts)
        np.testing.assert_allclose(np.asarray(logits_eval),
                                   np.asarray(logits_split),
                                   rtol=1e-5, atol=1e-5)

    def test_cross_entropy_uniform(self):
        logits = jnp.zeros((2, 7))
        y = jnp.array([3, 5], jnp.int32)
        assert float(M.cross_entropy(logits, y)) == pytest.approx(np.log(7), rel=1e-5)

    def test_deterministic_init(self):
        a = M.init_params(M.client_spec(CFG), jax.random.PRNGKey(42))
        b = M.init_params(M.client_spec(CFG), jax.random.PRNGKey(42))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_group_norm_normalizes(self):
        rng = np.random.RandomState(0)
        x = jnp.array(rng.randn(2, 8, 4, 4) * 10 + 5, jnp.float32)
        y = M.group_norm(x, jnp.ones(8), jnp.zeros(8), groups=4)
        yg = np.asarray(y).reshape(2, 4, 2, 4, 4)
        np.testing.assert_allclose(yg.mean(axis=(2, 3, 4)), 0.0, atol=1e-4)
        np.testing.assert_allclose(yg.std(axis=(2, 3, 4)), 1.0, atol=1e-2)
