"""AOT pipeline: HLO text well-formedness + manifest/blob consistency.

These run against a small throwaway config (batch=2) in a tmpdir so they
don't depend on `make artifacts` having run.
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.ModelConfig(name="ham", batch=2)
    aot.build_config(cfg, out, seed=0)
    return os.path.join(out, "ham"), cfg


def _manifest(built):
    with open(os.path.join(built[0], "manifest.json")) as f:
        return json.load(f)


EXPECTED_ARTIFACTS = ["client_fwd", "server_step", "client_bwd",
                      "eval_logits", "entropy", "qdq"]


class TestAotOutputs:
    def test_all_artifacts_written(self, built):
        d, _ = built
        man = _manifest(built)
        for name in EXPECTED_ARTIFACTS:
            assert name in man["artifacts"]
            path = os.path.join(d, man["artifacts"][name]["file"])
            assert os.path.getsize(path) > 100

    def test_hlo_text_is_parseable_hlo(self, built):
        """Every artifact must be HLO text with an ENTRY computation."""
        d, _ = built
        man = _manifest(built)
        for name in EXPECTED_ARTIFACTS:
            text = open(os.path.join(d, man["artifacts"][name]["file"])).read()
            assert "HloModule" in text, name
            assert "ENTRY" in text, name

    def test_manifest_shapes_match_model_spec(self, built):
        _, cfg = built
        man = _manifest(built)
        cspec = M.client_spec(cfg)
        for entry, (name, shape) in zip(man["client_params"], cspec):
            assert entry["name"] == name
            assert tuple(entry["dims"]) == shape
        cut = man["config"]["cut"]
        assert (cut["b"], cut["c"], cut["h"], cut["w"]) == cfg.cut_shape

    def test_init_blob_sizes(self, built):
        d, cfg = built
        man = _manifest(built)
        csize = os.path.getsize(os.path.join(d, "client_init.bin"))
        ssize = os.path.getsize(os.path.join(d, "server_init.bin"))
        assert csize == 4 * M.param_count(M.client_spec(cfg))
        assert ssize == 4 * M.param_count(M.server_spec(cfg))
        assert man["client_param_count"] == M.param_count(M.client_spec(cfg))

    def test_init_blob_roundtrip(self, built):
        """The blob deserializes to exactly the jax init (offset layout)."""
        d, cfg = built
        man = _manifest(built)
        blob = np.fromfile(os.path.join(d, "client_init.bin"), dtype="<f4")
        key = jax.random.PRNGKey(0)
        kc, _ = jax.random.split(key)
        cinit = M.init_params(M.client_spec(cfg), kc)
        for entry, arr in zip(man["client_params"], cinit):
            seg = blob[entry["offset"]:entry["offset"] + entry["size"]]
            np.testing.assert_array_equal(seg, np.asarray(arr).ravel())

    def test_server_step_io_counts(self, built):
        _, cfg = built
        man = _manifest(built)
        ss = man["artifacts"]["server_step"]
        nsp = len(M.server_spec(cfg))
        assert len(ss["inputs"]) == nsp + 3      # sp..., acts, y, lr
        assert len(ss["outputs"]) == nsp + 2     # loss, g_acts, sp'...

    def test_entropy_artifact_io(self, built):
        _, cfg = built
        man = _manifest(built)
        ent = man["artifacts"]["entropy"]
        assert [tuple(i["dims"]) for i in ent["inputs"]] == [cfg.cut_shape]
        assert tuple(ent["outputs"][0]["dims"]) == (cfg.width,)

    def test_deterministic_hlo(self, built, tmp_path):
        """Rebuilding yields byte-identical HLO (sha recorded in manifest)."""
        out2 = str(tmp_path / "rebuild")
        cfg = M.ModelConfig(name="ham", batch=2)
        aot.build_config(cfg, out2, seed=0)
        man1 = _manifest(built)
        with open(os.path.join(out2, "ham", "manifest.json")) as f:
            man2 = json.load(f)
        for name in EXPECTED_ARTIFACTS:
            assert man1["artifacts"][name]["sha256"] == \
                man2["artifacts"][name]["sha256"], name
