"""tools/plot.py: parsing + rendering of the bench JSON sidecars."""

import json
import os
import subprocess
import sys

TOOL = os.path.join(os.path.dirname(__file__), "..", "..", "tools", "plot.py")


def _doc():
    return {
        "title": "fig_test",
        "rows": [
            {"codec": "slacc", "final_acc%": 71.9, "MB_total": 12.3},
            {"codec": "powerquant", "final_acc%": 65.0, "MB_total": 20.0},
            {"series": "slacc_acc_vs_time",
             "points": [[0.0, 0.1], [1.0, 0.5], [2.0, 0.7]]},
            {"series": "powerquant_acc_vs_time",
             "points": [[0.0, 0.1], [1.5, 0.4], [3.0, 0.6]]},
        ],
    }


def test_plot_renders_table_and_chart(tmp_path):
    p = tmp_path / "fig_test.json"
    p.write_text(json.dumps(_doc()))
    out = subprocess.run(
        [sys.executable, TOOL, str(p)], capture_output=True, text=True, check=True
    )
    assert "fig_test" in out.stdout
    assert "slacc" in out.stdout
    assert "powerquant" in out.stdout
    # chart frame + legend markers
    assert "+----" in out.stdout.replace("-" * 20, "----")
    assert "o slacc_acc_vs_time" in out.stdout


def test_plot_no_files_is_graceful(tmp_path):
    out = subprocess.run(
        [sys.executable, TOOL, str(tmp_path / "nope*.json")],
        capture_output=True,
        text=True,
    )
    assert out.returncode == 1
    assert "no bench_results" in out.stdout


def test_plot_handles_flat_series(tmp_path):
    doc = {"title": "flat", "rows": [
        {"series": "s", "points": [[0.0, 0.5], [1.0, 0.5]]}]}
    p = tmp_path / "flat.json"
    p.write_text(json.dumps(doc))
    out = subprocess.run(
        [sys.executable, TOOL, str(p)], capture_output=True, text=True, check=True
    )
    assert "flat" in out.stdout
