"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/values; fixed cases pin the exact configurations
the AOT artifacts are built at (C=32, N=8192).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import entropy_kernel, qdq_kernel, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed=0, scale=1.0, offset=0.0):
    rng = np.random.RandomState(seed)
    return (rng.randn(*shape) * scale + offset).astype(np.float32)


# --------------------------------------------------------------------------
# channel entropy
# --------------------------------------------------------------------------

class TestEntropyKernel:
    def test_matches_ref_basic(self):
        x = _rand((8, 256))
        got = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
        want = np.asarray(ref.channel_entropy_ref(jnp.array(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_artifact_shape(self):
        """The exact (C, N) the AOT artifact is compiled at."""
        x = _rand((32, 32 * 16 * 16), seed=3)
        got = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
        want = np.asarray(ref.channel_entropy_ref(jnp.array(x)))
        assert got.shape == (32,)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_nchw_wrapper_matches_2d(self):
        acts = _rand((4, 8, 6, 6), seed=1)
        got = np.asarray(entropy_kernel.channel_entropy_nchw(jnp.array(acts)))
        x2d = np.transpose(acts, (1, 0, 2, 3)).reshape(8, -1)
        want = np.asarray(ref.channel_entropy_ref(jnp.array(x2d)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_constant_channel_max_entropy(self):
        """A flat channel normalizes to all-zeros -> uniform softmax -> ln N."""
        n = 128
        x = np.zeros((1, n), np.float32)
        got = float(entropy_kernel.channel_entropy(jnp.array(x))[0])
        assert got == pytest.approx(np.log(n), rel=1e-5)

    def test_peaked_channel_lower_entropy(self):
        """One huge element concentrates mass -> entropy below ln N."""
        n = 256
        x = np.zeros((1, n), np.float32)
        x[0, 0] = 1000.0
        flat = float(entropy_kernel.channel_entropy(jnp.zeros((1, n)))[0])
        peaked = float(entropy_kernel.channel_entropy(jnp.array(x))[0])
        assert peaked < flat

    def test_entropy_bounds(self):
        """0 <= H <= ln N for any input."""
        for seed in range(5):
            x = _rand((16, 333), seed=seed, scale=10 ** (seed - 2))
            h = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
            assert np.all(h >= 0.0)
            assert np.all(h <= np.log(333) + 1e-4)

    def test_shift_invariance(self):
        """Min-max normalization makes entropy shift-invariant."""
        x = _rand((4, 64), seed=7)
        h1 = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
        h2 = np.asarray(entropy_kernel.channel_entropy(jnp.array(x + 37.5)))
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)

    def test_scale_invariance(self):
        """...and positive-scale invariant."""
        x = _rand((4, 64), seed=8)
        h1 = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
        h2 = np.asarray(entropy_kernel.channel_entropy(jnp.array(x * 5.0)))
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        c=st.integers(1, 12),
        n=st.integers(2, 300),
        seed=st.integers(0, 2 ** 16),
        scale=st.floats(1e-3, 1e3),
    )
    def test_matches_ref_hypothesis(self, c, n, seed, scale):
        x = _rand((c, n), seed=seed, scale=scale)
        got = np.asarray(entropy_kernel.channel_entropy(jnp.array(x)))
        want = np.asarray(ref.channel_entropy_ref(jnp.array(x)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# quantize-dequantize
# --------------------------------------------------------------------------

def _qdq_params(x, bits):
    qmin = x.min(axis=1, keepdims=True)
    qmax = x.max(axis=1, keepdims=True)
    lv = np.full((x.shape[0], 1), float(2 ** bits - 1), np.float32)
    return qmin, qmax, lv


class TestQdqKernel:
    @pytest.mark.parametrize("bits", [2, 3, 4, 6, 8])
    def test_matches_ref(self, bits):
        x = _rand((8, 200), seed=bits)
        qmin, qmax, lv = _qdq_params(x, bits)
        got = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        want = np.asarray(ref.qdq_ref(*map(jnp.array, (x, qmin, qmax, lv))))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_error_bounded_by_half_step(self):
        """|x - qdq(x)| <= scale/2 + eps for in-range values."""
        x = _rand((4, 500), seed=11)
        qmin, qmax, lv = _qdq_params(x, 4)
        y = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        step = (qmax - qmin) / lv
        assert np.all(np.abs(x - y) <= step / 2 + 1e-5)

    def test_idempotent(self):
        """qdq(qdq(x)) == qdq(x): quantized values are fixed points."""
        x = _rand((4, 100), seed=12)
        qmin, qmax, lv = _qdq_params(x, 3)
        y1 = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        y2 = np.asarray(qdq_kernel.qdq(*map(jnp.array, (y1, qmin, qmax, lv))))
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)

    def test_flat_channel_collapses_to_qmin(self):
        x = np.full((2, 16), 3.25, np.float32)
        qmin = np.full((2, 1), 3.25, np.float32)
        qmax = np.full((2, 1), 3.25, np.float32)
        lv = np.full((2, 1), 15.0, np.float32)
        y = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        np.testing.assert_allclose(y, 3.25)

    def test_endpoints_exact(self):
        """qmin and qmax are representable exactly."""
        x = np.array([[0.0, 1.0, 0.5]], np.float32)
        qmin = np.array([[0.0]], np.float32)
        qmax = np.array([[1.0]], np.float32)
        lv = np.array([[3.0]], np.float32)
        y = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        np.testing.assert_allclose(y[0, 0], 0.0, atol=1e-7)
        np.testing.assert_allclose(y[0, 1], 1.0, atol=1e-7)

    def test_nchw_wrapper_roundtrip_shape(self):
        acts = _rand((4, 8, 6, 6), seed=13)
        qmin = acts.transpose(1, 0, 2, 3).reshape(8, -1).min(1, keepdims=True)
        qmax = acts.transpose(1, 0, 2, 3).reshape(8, -1).max(1, keepdims=True)
        lv = np.full((8, 1), 255.0, np.float32)
        y = np.asarray(qdq_kernel.qdq_nchw(*map(jnp.array, (acts, qmin, qmax, lv))))
        assert y.shape == acts.shape
        assert np.abs(y - acts).max() < (qmax - qmin).max() / 255.0

    @settings(max_examples=25, deadline=None)
    @given(
        c=st.integers(1, 8),
        n=st.integers(2, 200),
        bits=st.integers(2, 8),
        seed=st.integers(0, 2 ** 16),
    )
    def test_matches_ref_hypothesis(self, c, n, bits, seed):
        x = _rand((c, n), seed=seed, scale=3.0)
        qmin, qmax, lv = _qdq_params(x, bits)
        got = np.asarray(qdq_kernel.qdq(*map(jnp.array, (x, qmin, qmax, lv))))
        want = np.asarray(ref.qdq_ref(*map(jnp.array, (x, qmin, qmax, lv))))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
