//! Distributed SL over localhost TCP: one server process + 4 device-worker
//! processes, then a byte-for-byte parity check against the in-process
//! loopback path.
//!
//!     cargo run --release --example distributed
//!
//! The orchestrator re-spawns this example binary in `--role server` /
//! `--role device` mode (same idea as `slacc serve` / `slacc device`),
//! waits for the fleet to finish >= 3 training rounds, then runs the
//! identical config through the in-process loopback transport and asserts
//! that every round's `bytes_up`/`bytes_down` match exactly — the codec
//! envelopes on the wire are the ones the simulator always accounted.
//!
//! With AOT artifacts present this trains the real model through PJRT in
//! every process; without them it falls back to the deterministic mock
//! model (real codecs, real protocol, fake math — see
//! `slacc::transport::compute::MockCompute`).
//!
//! Flags: --rounds N [3] --devices N [4] --port P [47613] --seed N [0]
//!        --trace-dir DIR  record every process's lifecycle spans as
//!                         DIR/server.jsonl + DIR/deviceN.jsonl, ready for
//!                         `slacc trace DIR/*.jsonl`

use std::net::TcpListener;
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::{engine_runtime, engine_worker, Trainer};
use slacc::data::Dataset;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{accept_and_serve, mock_runtime, run_mock_loopback};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::Transport;

fn session_cfg(devices: usize, rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.seed = seed;
    cfg.train_n = 256;
    cfg.test_n = 64;
    cfg.lr = 1e-3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg
}

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let role = args.str_or("role", "main");
    let devices = args.usize_or("devices", 4);
    let rounds = args.usize_or("rounds", 3);
    let seed = args.usize_or("seed", 0) as u64;
    let port = args.usize_or("port", 47613);
    let id = args.usize_or("id", 0);
    let csv = args.str_opt("csv");
    let trace_dir = args.str_opt("trace-dir");
    let trace_out = args.str_opt("trace-out");
    args.finish()?;
    let cfg = session_cfg(devices, rounds, seed);
    cfg.validate()?;
    match role.as_str() {
        "main" => orchestrate(cfg, port, trace_dir),
        "server" => role_server(cfg, port, csv, trace_out),
        "device" => role_device(cfg, port, id, trace_out),
        other => Err(format!("unknown --role '{other}'")),
    }
}

/// Enable span recording for a spawned role and drain it at session end.
fn begin_trace(role: &'static str, trace_out: &Option<String>) {
    if trace_out.is_some() {
        slacc::obs::span::set_enabled(true);
        slacc::obs::span::set_trace_role(role, 0);
    }
}

fn end_trace(tag: &str, trace_out: &Option<String>) -> Result<(), String> {
    if let Some(path) = trace_out {
        let n = slacc::obs::span::write_jsonl(path)?;
        println!("[{tag}] {n} trace event(s) -> {path}");
    }
    Ok(())
}

fn role_server(
    cfg: ExperimentConfig,
    port: usize,
    csv: Option<String>,
    trace_out: Option<String>,
) -> Result<(), String> {
    begin_trace("server", &trace_out);
    let bind = format!("127.0.0.1:{port}");
    let listener = TcpListener::bind(&bind).map_err(|e| format!("bind {bind}: {e}"))?;
    println!("[server] listening on {bind} for {} devices", cfg.devices);
    let report = if cfg.have_artifacts() {
        let mut rt = engine_runtime(&cfg)?;
        accept_and_serve(&mut rt, &listener)?
    } else {
        let (_, test) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let mut rt = mock_runtime(&cfg, Arc::new(test))?;
        accept_and_serve(&mut rt, &listener)?
    };
    println!(
        "[server] {} rounds done: {:.2} KB up / {:.2} KB down",
        report.rounds_run,
        report.total_bytes_up as f64 / 1e3,
        report.total_bytes_down as f64 / 1e3
    );
    if let Some(path) = csv {
        report.metrics.write_csv(std::path::Path::new(&path))?;
    }
    end_trace("server", &trace_out)
}

fn role_device(
    cfg: ExperimentConfig,
    port: usize,
    id: usize,
    trace_out: Option<String>,
) -> Result<(), String> {
    begin_trace("device", &trace_out);
    let addr = format!("127.0.0.1:{port}");
    let mut conn = TcpTransport::connect_retry(&addr, 80, Duration::from_millis(250))?;
    if cfg.have_artifacts() {
        let mut worker = engine_worker(&cfg, id)?;
        run_blocking(&mut worker, &mut conn)?;
    } else {
        let (train, _) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let mut worker = mock_worker(&cfg, Arc::new(train), id)?;
        run_blocking(&mut worker, &mut conn)?;
    }
    println!("[device {id}] done ({} bytes sent)", conn.stats().bytes_sent);
    end_trace(&format!("device {id}"), &trace_out)
}

fn orchestrate(
    cfg: ExperimentConfig,
    port: usize,
    trace_dir: Option<String>,
) -> Result<(), String> {
    let exe = std::env::current_exe().map_err(|e| e.to_string())?;
    let csv = std::env::temp_dir()
        .join(format!("slacc_distributed_{}.csv", std::process::id()));
    let common = [
        ("--devices", cfg.devices.to_string()),
        ("--rounds", cfg.rounds.to_string()),
        ("--seed", cfg.seed.to_string()),
        ("--port", port.to_string()),
    ];
    println!(
        "orchestrator: {} devices x {} rounds over 127.0.0.1:{port} ({})",
        cfg.devices,
        cfg.rounds,
        if cfg.have_artifacts() { "PJRT artifacts" } else { "mock model" }
    );

    let traces = match &trace_dir {
        Some(dir) => {
            let dir = std::path::PathBuf::from(dir);
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("create {}: {e}", dir.display()))?;
            Some(dir)
        }
        None => None,
    };

    let mut server = Command::new(&exe);
    server.args(["--role", "server", "--csv", &csv.to_string_lossy()]);
    if let Some(dir) = &traces {
        server.args(["--trace-out", &dir.join("server.jsonl").to_string_lossy()]);
    }
    for (k, v) in &common {
        server.args([*k, v.as_str()]);
    }
    let mut server = server.spawn().map_err(|e| format!("spawn server: {e}"))?;

    let mut workers = Vec::new();
    for d in 0..cfg.devices {
        let mut c = Command::new(&exe);
        c.args(["--role", "device", "--id", &d.to_string()]);
        if let Some(dir) = &traces {
            c.args([
                "--trace-out",
                &dir.join(format!("device{d}.jsonl")).to_string_lossy(),
            ]);
        }
        for (k, v) in &common {
            c.args([*k, v.as_str()]);
        }
        workers.push(c.spawn().map_err(|e| format!("spawn device {d}: {e}"))?);
    }

    for (d, mut w) in workers.into_iter().enumerate() {
        let st = w.wait().map_err(|e| e.to_string())?;
        if !st.success() {
            let _ = server.kill();
            return Err(format!("device {d} exited with {st}"));
        }
    }
    let st = server.wait().map_err(|e| e.to_string())?;
    if !st.success() {
        return Err(format!("server exited with {st}"));
    }

    // per-round wire bytes from the TCP run
    let text = std::fs::read_to_string(&csv)
        .map_err(|e| format!("read {}: {e}", csv.display()))?;
    let tcp_rounds: Vec<(usize, usize)> = text
        .lines()
        .skip(1)
        .map(|line| {
            let f: Vec<&str> = line.split(',').collect();
            Ok((
                f[3].parse::<usize>().map_err(|e| format!("csv bytes_up: {e}"))?,
                f[4].parse::<usize>().map_err(|e| format!("csv bytes_down: {e}"))?,
            ))
        })
        .collect::<Result<_, String>>()?;
    let _ = std::fs::remove_file(&csv);

    // the same session through the in-process loopback transport
    println!("orchestrator: re-running in-process over loopback for parity check");
    let reference = if cfg.have_artifacts() {
        Trainer::new(cfg.clone())?.run()?
    } else {
        run_mock_loopback(&cfg)?
    };

    if tcp_rounds.len() != cfg.rounds {
        return Err(format!(
            "TCP session ran {} rounds, expected {}",
            tcp_rounds.len(),
            cfg.rounds
        ));
    }
    if tcp_rounds.len() != reference.metrics.records.len() {
        return Err(format!(
            "round-count mismatch: TCP {} vs loopback {}",
            tcp_rounds.len(),
            reference.metrics.records.len()
        ));
    }
    println!("round  tcp-up  loop-up  tcp-down  loop-down");
    let mut ok = true;
    for (i, (rec, &(up, down))) in
        reference.metrics.records.iter().zip(&tcp_rounds).enumerate()
    {
        let row_ok = rec.bytes_up == up && rec.bytes_down == down;
        ok &= row_ok;
        println!(
            "{:>5}  {:>6}  {:>7}  {:>8}  {:>9}  {}",
            i,
            up,
            rec.bytes_up,
            down,
            rec.bytes_down,
            if row_ok { "ok" } else { "MISMATCH" }
        );
    }
    if !ok {
        return Err("TCP and loopback sessions disagree on wire bytes".into());
    }
    println!(
        "PARITY OK: {} rounds, {} devices — TCP wire bytes identical to the \
         in-process loopback run",
        tcp_rounds.len(),
        cfg.devices
    );
    if let Some(dir) = &traces {
        println!(
            "traces recorded under {0} — analyze with: slacc trace {0}/*.jsonl",
            dir.display()
        );
    }
    Ok(())
}
