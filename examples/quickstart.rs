//! Quickstart: train a split GN-ResNet on synthetic HAM10000 with SL-ACC
//! compression for 40 rounds and print the loss/accuracy curve.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the full three-layer stack: the Rust coordinator drives
//! the AOT-compiled JAX model (client_fwd / server_step / client_bwd) and
//! the Pallas channel-entropy kernel through PJRT; ACII+CGC compresses
//! every smashed-data transfer in both directions.

use slacc::config::ExperimentConfig;
use slacc::coordinator::trainer::Trainer;

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();

    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.rounds = 40;
    cfg.train_n = 600;
    cfg.test_n = 128;
    cfg.eval_every = 5;
    cfg.lr = 3e-3;

    println!("SL-ACC quickstart: {} devices, {} rounds, codec={}",
             cfg.devices, cfg.rounds, cfg.codec.label());
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;

    println!("\nround  loss    accuracy  sim-time");
    for r in &report.metrics.records {
        match r.accuracy {
            Some(a) => println!(
                "{:>5}  {:.4}  {:>6.2}%   {:>7.1}s",
                r.round, r.loss, a * 100.0, r.sim_time_s
            ),
            None => {}
        }
    }
    println!(
        "\nfinal accuracy {:.2}% | {:.2} MB up / {:.2} MB down | sim {:.1}s",
        report.final_accuracy * 100.0,
        report.total_bytes_up as f64 / 1e6,
        report.total_bytes_down as f64 / 1e6,
        report.total_sim_time_s
    );
    Ok(())
}
