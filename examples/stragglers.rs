//! Straggler handling over real sockets: a 5-device mock fleet where one
//! device is ~10x slower than the rest, served by the single-threaded poll
//! event loop under `ArrivalOrder { straggler_timeout, min_quorum }`.
//!
//!     cargo run --release --example stragglers
//!
//! The point being demonstrated (and asserted):
//! * every round completes without blocking on the slow device — total
//!   wall time stays well under the `rounds x slow_delay` floor that the
//!   default InOrder schedule would pay;
//! * the slow device is carried (straggler events > 0) and its stale
//!   rounds are served when they finally land;
//! * ModelSync traffic is byte-accounted on its own axis.
//!
//! Engine-free on purpose: the mock model runs the real codecs, the real
//! framed protocol, and the real scheduler — only the model math is fake,
//! so this example works with zero PJRT artifacts (e.g. in CI).
//!
//! Flags: --rounds N [6] --devices N [5] --slow-ms N [500] --timeout-ms N [120]

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::data::Dataset;
use slacc::sched::Policy;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{accept_and_serve, mock_runtime};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::DelayedTransport;

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let rounds = args.usize_or("rounds", 6);
    let devices = args.usize_or("devices", 5);
    let slow_ms = args.usize_or("slow-ms", 500);
    let timeout_ms = args.usize_or("timeout-ms", 120);
    args.finish()?;
    if devices < 2 {
        return Err("need at least 2 devices (one of them slow)".into());
    }

    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.train_n = 128;
    cfg.test_n = 16;
    cfg.eval_every = rounds.max(1);
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg.schedule = Policy::arrival_with_timeout(
        timeout_ms as f64 / 1e3,
        devices - 1, // close once everyone but the straggler delivered
    );
    cfg.validate()?;

    let listener =
        TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bind: {e}"))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?.to_string();
    println!(
        "stragglers: {devices} devices x {rounds} rounds on {addr}; device {} \
         sleeps {slow_ms} ms per round (timeout {timeout_ms} ms)",
        devices - 1
    );

    let slow_id = devices - 1;
    let mut handles = Vec::new();
    for d in 0..devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        let delay = Duration::from_millis(slow_ms as u64);
        handles.push(thread::spawn(move || -> Result<(), String> {
            let (train, _) =
                Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
            let mut worker = mock_worker(&cfg, Arc::new(train), d)?;
            let inner =
                TcpTransport::connect_retry(&addr, 80, Duration::from_millis(100))?;
            if d == cfg.devices - 1 {
                let mut conn = DelayedTransport::slow_activations(inner, delay);
                run_blocking(&mut worker, &mut conn)
            } else {
                let mut conn = inner;
                run_blocking(&mut worker, &mut conn)
            }
        }));
    }

    let (_, test) = Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let mut rt = mock_runtime(&cfg, Arc::new(test))?;
    let t0 = Instant::now();
    let report = accept_and_serve(&mut rt, &listener)?;
    let wall = t0.elapsed();

    println!("\nround  participants  stragglers  max_wait_ms");
    for rec in rt.sched_records() {
        println!(
            "{:>5}  {:>12}  {:>10}  {:>11.1}",
            rec.round,
            rec.participants.len(),
            rec.stragglers.len(),
            rec.max_wait_s() * 1e3
        );
    }
    println!(
        "\n{} rounds in {:.0} ms wall; {} straggler carry-overs; \
         {:.1} KB smashed / {:.1} KB sync",
        report.rounds_run,
        wall.as_secs_f64() * 1e3,
        report.straggler_events,
        (report.total_bytes_up + report.total_bytes_down) as f64 / 1e3,
        report.total_bytes_sync as f64 / 1e3,
    );

    // the InOrder floor: every round waits the full slow-device delay
    let blocking_floor = Duration::from_millis((slow_ms * rounds) as u64);
    if report.rounds_run != rounds {
        return Err(format!("ran {} rounds, wanted {rounds}", report.rounds_run));
    }
    if report.straggler_events == 0 {
        return Err("the slow device was never carried as a straggler".into());
    }
    if wall >= blocking_floor {
        return Err(format!(
            "fleet blocked on the straggler: {wall:?} >= {blocking_floor:?}"
        ));
    }
    println!(
        "OK: arrival-order fleet finished in {:.0} ms < {:.0} ms in-order floor \
         (device {slow_id} was carried, not waited on)",
        wall.as_secs_f64() * 1e3,
        blocking_floor.as_secs_f64() * 1e3
    );

    // fast devices must exit cleanly; the straggler may have been cut off
    // by session end mid-sleep (acceptable — the server no longer waits)
    for (d, h) in handles.into_iter().enumerate() {
        let out = h.join().map_err(|_| format!("device {d} panicked"))?;
        if d != slow_id {
            out?;
        }
    }
    Ok(())
}
