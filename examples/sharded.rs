//! Multi-server sharded SL over localhost TCP: one coordinator process +
//! 2 shard-server processes + 4 device-worker processes.
//!
//!     cargo run --release --example sharded
//!
//! The orchestrator re-spawns this example binary in `--role coordinator`
//! / `--role shard` / `--role device` mode (the same topology `slacc
//! serve --role ...` deploys), waits for the cluster to finish, then — in
//! mock mode — runs the identical config through the in-process
//! channel-transport simulation (`run_sharded_mock`) and asserts that
//! every shard's per-round `bytes_up`/`bytes_down`/`bytes_sync` match
//! exactly: the cross-shard sync tier moves the same bytes over real
//! sockets as over the deterministic in-process fabric.
//!
//! With AOT artifacts present every process trains the real model through
//! PJRT (no in-process reference — PJRT objects never cross threads); the
//! cluster is still asserted to complete every round on every shard.
//!
//! Flags: --rounds N [4] --devices N [4] --shards N [2]
//!        --sync-every N [1] --port P [47710] --seed N [0]

use std::net::TcpListener;
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::engine_runtime_for_shard;
use slacc::data::Dataset;
use slacc::sched::fleet::ShardFleet;
use slacc::shard::coordinator::Coordinator;
use slacc::shard::link::ShardLink;
use slacc::shard::sim::run_sharded_mock;
use slacc::transport::device::{mock_worker, run_blocking};
use slacc::transport::server::{accept_and_serve, mock_runtime_for_shard};
use slacc::transport::tcp::TcpTransport;
use slacc::transport::{session_fingerprint, Transport};

fn session_cfg(
    devices: usize,
    shards: usize,
    rounds: usize,
    sync_every: usize,
    seed: u64,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("ham");
    cfg.devices = devices;
    cfg.rounds = rounds;
    cfg.seed = seed;
    cfg.train_n = 256;
    cfg.test_n = 64;
    cfg.lr = 1e-3;
    cfg.codec = CodecChoice::Named("slacc".into());
    cfg.shards = shards;
    cfg.shard_sync_every = sync_every;
    cfg
}

/// Port layout under `--port P`: shard k's device listener is `P + k`,
/// its coordinator listener `P + 100 + k`.
fn dev_port(base: usize, shard: usize) -> usize {
    base + shard
}

fn shard_port(base: usize, shard: usize) -> usize {
    base + 100 + shard
}

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let role = args.str_or("role", "main");
    let devices = args.usize_or("devices", 4);
    let shards = args.usize_or("shards", 2);
    let rounds = args.usize_or("rounds", 4);
    let sync_every = args.usize_or("sync-every", 1);
    let seed = args.usize_or("seed", 0) as u64;
    let port = args.usize_or("port", 47710);
    let id = args.usize_or("id", 0);
    let csv = args.str_opt("csv");
    args.finish()?;
    let cfg = session_cfg(devices, shards, rounds, sync_every, seed);
    cfg.validate()?;
    match role.as_str() {
        "main" => orchestrate(cfg, port),
        "coordinator" => role_coordinator(cfg, port),
        "shard" => role_shard(cfg, port, id, csv),
        "device" => role_device(cfg, port, id),
        other => Err(format!("unknown --role '{other}'")),
    }
}

fn role_coordinator(cfg: ExperimentConfig, port: usize) -> Result<(), String> {
    let kind = if cfg.have_artifacts() { "engine" } else { "mock" };
    let mut conns: Vec<Box<dyn Transport>> = Vec::with_capacity(cfg.shards);
    for k in 0..cfg.shards {
        let addr = format!("127.0.0.1:{}", shard_port(port, k));
        conns.push(Box::new(TcpTransport::connect_retry(
            &addr,
            120,
            Duration::from_millis(250),
        )?));
    }
    let mut coordinator = Coordinator::from_experiment(&cfg, kind)?;
    let mut fleet = ShardFleet::new(conns);
    let report = coordinator.run(&mut fleet)?;
    println!(
        "[coordinator] {} shards, {} sync epochs, {:.2} KB up / {:.2} KB down",
        report.shards,
        report.sync_epochs,
        report.bytes_up as f64 / 1e3,
        report.bytes_down as f64 / 1e3
    );
    Ok(())
}

fn role_shard(
    cfg: ExperimentConfig,
    port: usize,
    shard_id: usize,
    csv: Option<String>,
) -> Result<(), String> {
    let topo = cfg.topology();
    let shape = topo.shape_for(cfg.devices, shard_id);
    let shard_bind = format!("127.0.0.1:{}", shard_port(port, shard_id));
    let shard_listener =
        TcpListener::bind(&shard_bind).map_err(|e| format!("bind {shard_bind}: {e}"))?;
    println!("[shard {shard_id}] waiting for the coordinator on {shard_bind}");
    let coord_conn = TcpTransport::accept_direct(&shard_listener)?;

    let (train, test) =
        Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    let weight = slacc::shard::shard_weight(&cfg, &train, shard_id);
    let kind = if cfg.have_artifacts() { "engine" } else { "mock" };
    let session_fp = session_fingerprint(cfg.fingerprint(), kind);
    let link = ShardLink::handshake(
        Box::new(coord_conn),
        &topo,
        shard_id,
        weight,
        session_fp,
        cfg.shard_link_streams(shard_id)?,
    )?;

    let dev_bind = format!("127.0.0.1:{}", dev_port(port, shard_id));
    let listener =
        TcpListener::bind(&dev_bind).map_err(|e| format!("bind {dev_bind}: {e}"))?;
    println!(
        "[shard {shard_id}] serving devices {}..{} on {dev_bind}",
        shape.base,
        shape.base + shape.local
    );
    let report = if cfg.have_artifacts() {
        let mut rt = engine_runtime_for_shard(&cfg, shard_id)?;
        rt.attach_shard_link(link);
        accept_and_serve(&mut rt, &listener)?
    } else {
        let mut rt = mock_runtime_for_shard(&cfg, shard_id, Arc::new(test))?;
        rt.attach_shard_link(link);
        accept_and_serve(&mut rt, &listener)?
    };
    println!(
        "[shard {shard_id}] {} rounds done: {:.2} KB up / {:.2} KB sync",
        report.rounds_run,
        report.total_bytes_up as f64 / 1e3,
        report.total_bytes_sync as f64 / 1e3
    );
    if let Some(path) = csv {
        report.metrics.write_csv(std::path::Path::new(&path))?;
    }
    Ok(())
}

fn role_device(cfg: ExperimentConfig, port: usize, id: usize) -> Result<(), String> {
    let shape = cfg.topology().shape_for(cfg.devices, 0);
    let shard = id / shape.local; // contiguous ranges: id's serving shard
    let addr = format!("127.0.0.1:{}", dev_port(port, shard));
    let mut conn = TcpTransport::connect_retry(&addr, 120, Duration::from_millis(250))?;
    if cfg.have_artifacts() {
        let mut worker = slacc::coordinator::trainer::engine_worker(&cfg, id)?;
        run_blocking(&mut worker, &mut conn)?;
    } else {
        let (train, _) =
            Dataset::for_config(&cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
        let mut worker = mock_worker(&cfg, Arc::new(train), id)?;
        run_blocking(&mut worker, &mut conn)?;
    }
    println!("[device {id}] done ({} bytes sent)", conn.stats().bytes_sent);
    Ok(())
}

fn orchestrate(cfg: ExperimentConfig, port: usize) -> Result<(), String> {
    let exe = std::env::current_exe().map_err(|e| e.to_string())?;
    let mock = !cfg.have_artifacts();
    println!(
        "orchestrator: {} devices x {} rounds across {} shards (+1 coordinator) \
         over 127.0.0.1:{port}.. ({})",
        cfg.devices,
        cfg.rounds,
        cfg.shards,
        if mock { "mock model" } else { "PJRT artifacts" }
    );
    let common = [
        ("--devices", cfg.devices.to_string()),
        ("--shards", cfg.shards.to_string()),
        ("--rounds", cfg.rounds.to_string()),
        ("--sync-every", cfg.shard_sync_every.to_string()),
        ("--seed", cfg.seed.to_string()),
        ("--port", port.to_string()),
    ];
    let spawn = |extra: &[(&str, String)]| -> Result<std::process::Child, String> {
        let mut c = Command::new(&exe);
        for (k, v) in extra {
            c.args([*k, v.as_str()]);
        }
        for (k, v) in &common {
            c.args([*k, v.as_str()]);
        }
        c.spawn().map_err(|e| format!("spawn: {e}"))
    };

    let mut csvs = Vec::new();
    let mut shards = Vec::new();
    for k in 0..cfg.shards {
        let csv = std::env::temp_dir()
            .join(format!("slacc_sharded_{}_{k}.csv", std::process::id()));
        shards.push(spawn(&[
            ("--role", "shard".into()),
            ("--id", k.to_string()),
            ("--csv", csv.to_string_lossy().into_owned()),
        ])?);
        csvs.push(csv);
    }
    let mut coordinator = spawn(&[("--role", "coordinator".into())])?;
    let mut devices = Vec::new();
    for g in 0..cfg.devices {
        devices.push(spawn(&[("--role", "device".into()), ("--id", g.to_string())])?);
    }

    // on any failure, kill AND reap every remaining child — a dead shard
    // leaves the coordinator and sibling devices blocked on sockets, and
    // an unreaped child is a zombie until this process exits
    fn kill_wait(procs: &mut [std::process::Child]) {
        for p in procs.iter_mut() {
            let _ = p.kill(); // errors on already-exited children expected
        }
        for p in procs.iter_mut() {
            let _ = p.wait();
        }
    }
    for g in 0..devices.len() {
        let st = devices[g].wait().map_err(|e| e.to_string())?;
        if !st.success() {
            kill_wait(&mut devices);
            kill_wait(std::slice::from_mut(&mut coordinator));
            kill_wait(&mut shards);
            return Err(format!("device {g} exited with {st}"));
        }
    }
    let st = coordinator.wait().map_err(|e| e.to_string())?;
    if !st.success() {
        kill_wait(&mut shards);
        return Err(format!("coordinator exited with {st}"));
    }
    // wait (and thereby reap) every shard before reporting the first bad one
    let mut shard_fail = None;
    for (k, s) in shards.iter_mut().enumerate() {
        let st = s.wait().map_err(|e| e.to_string())?;
        if !st.success() && shard_fail.is_none() {
            shard_fail = Some(format!("shard {k} exited with {st}"));
        }
    }
    if let Some(err) = shard_fail {
        return Err(err);
    }

    // per-shard per-round wire bytes from the TCP run
    let mut tcp_rounds: Vec<Vec<(usize, usize, usize)>> = Vec::new();
    for (k, csv) in csvs.iter().enumerate() {
        let text = std::fs::read_to_string(csv)
            .map_err(|e| format!("read {}: {e}", csv.display()))?;
        let rows: Vec<(usize, usize, usize)> = text
            .lines()
            .skip(1)
            .map(|line| {
                let f: Vec<&str> = line.split(',').collect();
                Ok((
                    f[3].parse::<usize>().map_err(|e| format!("bytes_up: {e}"))?,
                    f[4].parse::<usize>().map_err(|e| format!("bytes_down: {e}"))?,
                    f[7].parse::<usize>().map_err(|e| format!("bytes_sync: {e}"))?,
                ))
            })
            .collect::<Result<_, String>>()?;
        if rows.len() != cfg.rounds {
            return Err(format!(
                "shard {k} ran {} rounds, expected {}",
                rows.len(),
                cfg.rounds
            ));
        }
        tcp_rounds.push(rows);
        let _ = std::fs::remove_file(csv);
    }

    if !mock {
        println!(
            "CLUSTER OK: {} shards x {} rounds over TCP with PJRT artifacts \
             (in-process parity reference needs mock mode)",
            cfg.shards, cfg.rounds
        );
        return Ok(());
    }

    // the same cluster through the in-process channel-transport fabric
    println!("orchestrator: re-running in-process for the parity check");
    let reference = run_sharded_mock(&cfg)?;
    let mut ok = true;
    println!("shard round  tcp-up  sim-up  tcp-down  sim-down  tcp-sync  sim-sync");
    for (k, (tcp, sim)) in
        tcp_rounds.iter().zip(&reference.shard_reports).enumerate()
    {
        for (r, (&(up, down, sync), rec)) in
            tcp.iter().zip(&sim.metrics.records).enumerate()
        {
            let row_ok =
                up == rec.bytes_up && down == rec.bytes_down && sync == rec.bytes_sync;
            ok &= row_ok;
            println!(
                "{k:>5} {r:>5}  {up:>6}  {:>6}  {down:>8}  {:>8}  {sync:>8}  {:>8}  {}",
                rec.bytes_up,
                rec.bytes_down,
                rec.bytes_sync,
                if row_ok { "ok" } else { "MISMATCH" }
            );
        }
    }
    if !ok {
        return Err("TCP cluster and in-process simulation disagree on wire bytes".into());
    }
    println!(
        "PARITY OK: {} shards x {} devices x {} rounds — TCP cluster bytes \
         identical to the in-process topology simulation",
        cfg.shards,
        cfg.devices,
        cfg.rounds
    );
    Ok(())
}
