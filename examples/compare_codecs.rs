//! Codec shoot-out on live training: run every compression scheme for the
//! same short training budget and compare accuracy, bytes, and simulated
//! time — a fast preview of the paper's Fig. 5 before running the full
//! `cargo bench --bench fig5_main`.
//!
//!     make artifacts && cargo run --release --example compare_codecs
//!
//! Flags: --rounds N --dataset ham|mnist --noniid

use slacc::bench::Table;
use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::Trainer;
use slacc::data::partition::Partition;

const CODECS: &[&str] = &["identity", "slacc", "powerquant", "randtopk", "splitfc",
                          "easyquant", "uniform4"];

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let rounds = args.usize_or("rounds", 60);
    let dataset = args.str_or("dataset", "ham");
    let noniid = args.bool_or("noniid", false);
    args.finish()?;

    let mut table = Table::new(
        &format!("codec comparison ({dataset}, {rounds} rounds)"),
        &["codec", "final_acc%", "best_acc%", "MB_up", "MB_down", "sim_time_s"],
    );

    for name in CODECS {
        let mut cfg = ExperimentConfig::default_for(&dataset);
        cfg.rounds = rounds;
        cfg.train_n = 800;
        cfg.test_n = 256;
        cfg.eval_every = 10;
        cfg.lr = 3e-3;
        cfg.codec = CodecChoice::Named(name.to_string());
        if noniid {
            cfg.partition = Partition::Dirichlet { beta: 0.5 };
        }
        let mut trainer = Trainer::new(cfg)?;
        let r = trainer.run()?;
        table.row(vec![
            name.to_string(),
            format!("{:.2}", r.final_accuracy * 100.0),
            format!("{:.2}", r.best_accuracy * 100.0),
            format!("{:.2}", r.total_bytes_up as f64 / 1e6),
            format!("{:.2}", r.total_bytes_down as f64 / 1e6),
            format!("{:.2}", r.total_sim_time_s),
        ]);
        eprintln!("[done] {name}");
    }
    table.finish();
    Ok(())
}
