//! ACII/CGC internals on real activations: run one client forward pass,
//! compute per-channel entropy through BOTH the AOT Pallas kernel and the
//! host mirror (printing the parity error), then show the CGC grouping,
//! bit allocation, and payload layout for the batch.
//!
//!     make artifacts && cargo run --release --example inspect_entropy
//!
//! Flags: --dataset ham|mnist --groups N

use slacc::cli::Args;
use slacc::codecs::slacc::{SlAccCodec, SlAccConfig};
use slacc::codecs::{Codec, RoundCtx};
use slacc::data::Dataset;
use slacc::entropy::shannon;
use slacc::runtime::{Arg, Engine};

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let dataset = args.str_or("dataset", "ham");
    let groups = args.usize_or("groups", 4);
    args.finish()?;

    let dir = std::path::Path::new("artifacts").join(&dataset);
    let mut engine = Engine::load(&dir)?;
    let man = engine.manifest().clone();
    println!(
        "model {}: batch={} cut=({},{},{},{})",
        man.config_name, man.batch, man.cut.b, man.cut.c, man.cut.h, man.cut.w
    );

    // one real batch through the client sub-model
    let (train, _) = Dataset::for_config(&dataset, man.batch * 2, 1, 7)?;
    let idx: Vec<usize> = (0..man.batch).collect();
    let (x, _) = train.batch(&idx);
    let x_dims = [man.batch, man.in_ch, man.img, man.img];
    let cp = man.load_client_init()?;
    let mut eng_args: Vec<Arg> = cp.iter().map(|t| Arg::F32(t.data(), t.dims())).collect();
    eng_args.push(Arg::F32(&x, &x_dims));
    let acts = engine
        .execute("client_fwd", &eng_args)?
        .into_iter()
        .next()
        .unwrap();

    // entropy: Pallas kernel (AOT) vs host mirror
    let kernel_h = engine
        .execute("entropy", &[Arg::F32(acts.data(), acts.dims())])?
        .into_iter()
        .next()
        .unwrap()
        .into_data();
    let cm = acts.to_channel_major();
    let host_h = shannon::entropies(&cm);
    let max_err = kernel_h
        .iter()
        .zip(&host_h)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "\nentropy parity: Pallas kernel vs host mirror, max |err| = {max_err:.2e} \
         (N = {} elements/channel, ln N = {:.3})",
        cm.n_per_channel,
        (cm.n_per_channel as f32).ln()
    );

    // CGC grouping + bit allocation
    let cfg = SlAccConfig { groups, ..Default::default() };
    let mut codec = SlAccCodec::new(cfg, man.cut.c, 100, 0);
    let wire = codec.compress(&cm, RoundCtx { entropy: Some(&kernel_h) });
    let last = codec.last_round().unwrap().clone();

    println!("\nch  H(kernel)  H(blend)  group  bits");
    for c in 0..man.cut.c {
        println!(
            "{:>2}  {:>9.4}  {:>8.4}  {:>5}  {:>4}",
            c,
            kernel_h[c],
            last.blended_entropy[c],
            last.group_of_channel[c],
            last.group_bits[last.group_of_channel[c]]
        );
    }
    println!("\ngroup  mean-H  bits  members");
    for (j, (&h, &b)) in last.group_entropy.iter().zip(&last.group_bits).enumerate() {
        let members: Vec<String> = last
            .group_of_channel
            .iter()
            .enumerate()
            .filter(|&(_, &g)| g == j)
            .map(|(c, _)| c.to_string())
            .collect();
        println!("{:>5}  {:>6.4}  {:>4}  [{}]", j, h, b, members.join(","));
    }
    let raw = cm.data().len() * 4;
    println!(
        "\npayload: {} bytes (raw {} bytes, ratio {:.1}x, avg {:.2} bits/elem)",
        wire.len(),
        raw,
        raw as f64 / wire.len() as f64,
        last.avg_bits_per_element
    );

    // verify the decompressed tensor round-trips within quantization error
    let rec = codec.decode(&wire)?;
    println!("reconstruction mean|err| = {:.5}", acts.mean_abs_diff(&rec));
    Ok(())
}
