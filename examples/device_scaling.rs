//! Device-scaling study — the paper's motivating claim: "as the number of
//! participating devices increases, the transmission of excessive smashed
//! data becomes a major bottleneck" (Sec. I). Sweeps the fleet size and
//! reports per-round smashed-data volume and simulated round time for
//! uncompressed SL vs SL-ACC, including a heterogeneous fleet with a 4x
//! straggler.
//!
//!     make artifacts && cargo run --release --example device_scaling
//!
//! Flags: --rounds N (default 8) --dataset ham|mnist

use slacc::bench::Table;
use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::Trainer;

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let rounds = args.usize_or("rounds", 8);
    let dataset = args.str_or("dataset", "ham");
    args.finish()?;

    let mut table = Table::new(
        &format!("device scaling ({dataset}, {rounds} rounds)"),
        &["devices", "codec", "MB/round", "sim_s/round", "straggler"],
    );

    for &devices in &[2usize, 5, 8] {
        for codec in ["identity", "slacc"] {
            for hetero in [false, true] {
                let mut cfg = ExperimentConfig::default_for(&dataset);
                cfg.devices = devices;
                cfg.rounds = rounds;
                cfg.train_n = 64 * devices;
                cfg.test_n = 64;
                cfg.eval_every = rounds; // single eval at the end
                cfg.codec = CodecChoice::Named(codec.into());
                if hetero {
                    // one 4x straggler, rest nominal
                    cfg.device_speeds =
                        (0..devices).map(|d| if d == 0 { 0.25 } else { 1.0 }).collect();
                }
                let mut trainer = Trainer::new(cfg)?;
                let r = trainer.run()?;
                let mb_per_round = (r.total_bytes_up + r.total_bytes_down) as f64
                    / 1e6
                    / r.rounds_run as f64;
                let s_per_round = r.total_sim_time_s / r.rounds_run as f64;
                table.row(vec![
                    devices.to_string(),
                    codec.to_string(),
                    format!("{mb_per_round:.2}"),
                    format!("{s_per_round:.3}"),
                    if hetero { "4x".into() } else { "-".into() },
                ]);
            }
        }
    }
    table.finish();
    println!(
        "\nshape check: identity MB/round grows linearly with devices; SL-ACC cuts\n\
         it ~6-8x; the straggler dominates round time exactly as the paper's\n\
         bottleneck argument predicts."
    );
    Ok(())
}
