//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains the split GN-ResNet on the synthetic HAM10000 workload for a few
//! hundred rounds with SL-ACC compression active on both smashed-data
//! directions, and logs the full loss/accuracy curve plus communication
//! accounting. An uncompressed (identity) run follows as the reference so
//! the compression/accuracy trade-off is visible in one shot.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! Flags: --rounds N --train-n N --dataset ham|mnist --skip-identity

use slacc::cli::Args;
use slacc::config::{CodecChoice, ExperimentConfig};
use slacc::coordinator::trainer::Trainer;

fn run(cfg: ExperimentConfig) -> Result<slacc::coordinator::trainer::TrainReport, String> {
    let label = cfg.codec.label();
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n--- {label} ---");
    println!("round  loss    acc%     sim-time   cum-MB-up");
    let mut cum_up = 0usize;
    for r in &report.metrics.records {
        cum_up += r.bytes_up;
        if let Some(a) = r.accuracy {
            println!(
                "{:>5}  {:.4}  {:>6.2}  {:>8.1}s  {:>9.2}",
                r.round,
                r.loss,
                a * 100.0,
                r.sim_time_s,
                cum_up as f64 / 1e6
            );
        }
    }
    println!(
        "{label}: final {:.2}% best {:.2}% | sim {:.1}s | wall {wall:.0}s | {:.1} MB up",
        report.final_accuracy * 100.0,
        report.best_accuracy * 100.0,
        report.total_sim_time_s,
        report.total_bytes_up as f64 / 1e6,
    );
    Ok(report)
}

fn main() -> Result<(), String> {
    slacc::util::logging::init_from_env();
    let mut args = Args::from_env();
    let rounds = args.usize_or("rounds", 300);
    let train_n = args.usize_or("train-n", 2000);
    let dataset = args.str_or("dataset", "ham");
    let skip_identity = args.bool_or("skip-identity", false);
    args.finish()?;

    let mut cfg = ExperimentConfig::default_for(&dataset);
    cfg.rounds = rounds;
    cfg.train_n = train_n;
    cfg.test_n = 512;
    cfg.eval_every = 10;
    cfg.lr = 3e-3;

    let mut slacc_cfg = cfg.clone();
    slacc_cfg.codec = CodecChoice::Named("slacc".into());
    let slacc_report = run(slacc_cfg)?;
    slacc_report
        .metrics
        .write_csv(std::path::Path::new("bench_results/e2e_slacc.csv"))?;

    if !skip_identity {
        let mut id_cfg = cfg.clone();
        id_cfg.codec = CodecChoice::Named("identity".into());
        let id_report = run(id_cfg)?;
        id_report
            .metrics
            .write_csv(std::path::Path::new("bench_results/e2e_identity.csv"))?;

        println!("\n=== e2e summary ({dataset}, {rounds} rounds) ===");
        println!(
            "SL-ACC  : {:.2}% acc, {:.1}s sim, {:.1} MB",
            slacc_report.final_accuracy * 100.0,
            slacc_report.total_sim_time_s,
            (slacc_report.total_bytes_up + slacc_report.total_bytes_down) as f64 / 1e6
        );
        println!(
            "identity: {:.2}% acc, {:.1}s sim, {:.1} MB",
            id_report.final_accuracy * 100.0,
            id_report.total_sim_time_s,
            (id_report.total_bytes_up + id_report.total_bytes_down) as f64 / 1e6
        );
        let speedup = id_report.total_sim_time_s / slacc_report.total_sim_time_s.max(1e-9);
        println!("SL-ACC simulated-time speedup over uncompressed SL: {speedup:.2}x");
    }
    println!("\nCSV curves in bench_results/e2e_*.csv");
    Ok(())
}
