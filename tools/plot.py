#!/usr/bin/env python3
"""Render bench_results/*.json into terminal/markdown plots.

The Rust bench harness saves every figure's table rows plus the raw
accuracy-vs-round / accuracy-vs-time series as JSON sidecars. This tool
draws them as unicode line charts so the paper-figure *shapes* (who wins,
where curves cross) can be inspected without matplotlib (not installed on
this image).

Usage:
    python tools/plot.py                      # plot every saved result
    python tools/plot.py bench_results/fig5*  # subset
"""

import glob
import json
import sys

WIDTH = 72
HEIGHT = 14
MARKS = "ox+*#@%&"


def load(path):
    with open(path) as f:
        return json.load(f)


def series_of(doc):
    out = []
    for row in doc.get("rows", []):
        if "series" in row:
            pts = [(float(x), float(y)) for x, y in row["points"]]
            if pts:
                out.append((row["series"], pts))
    return out


def ascii_plot(title, named_series):
    xs = [x for _, pts in named_series for x, _ in pts]
    ys = [y for _, pts in named_series for _, y in pts]
    if not xs:
        return
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    if x1 - x0 < 1e-12:
        x1 = x0 + 1.0
    if y1 - y0 < 1e-12:
        y1 = y0 + 1.0
    grid = [[" "] * WIDTH for _ in range(HEIGHT)]
    for si, (_, pts) in enumerate(named_series):
        mark = MARKS[si % len(MARKS)]
        for x, y in pts:
            col = int((x - x0) / (x1 - x0) * (WIDTH - 1))
            row = HEIGHT - 1 - int((y - y0) / (y1 - y0) * (HEIGHT - 1))
            grid[row][col] = mark
    print(f"\n--- {title} ---")
    print(f"y: [{y0:.3f}, {y1:.3f}]   x: [{x0:.1f}, {x1:.1f}]")
    for row in grid:
        print("|" + "".join(row) + "|")
    print("+" + "-" * WIDTH + "+")
    for si, (name, _) in enumerate(named_series):
        print(f"  {MARKS[si % len(MARKS)]} {name}")


def print_table(doc):
    rows = [r for r in doc.get("rows", []) if "series" not in r]
    if not rows:
        return
    cols = list(rows[0].keys())
    widths = {c: max(len(c), max(len(str(r.get(c, ""))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))


def main():
    patterns = sys.argv[1:] or ["bench_results/*.json"]
    paths = sorted(p for pat in patterns for p in glob.glob(pat))
    if not paths:
        print("no bench_results/*.json found — run `make bench` first")
        return 1
    for path in paths:
        doc = load(path)
        print(f"\n================ {doc.get('title', path)} ================")
        print_table(doc)
        named = series_of(doc)
        if named:
            ascii_plot(doc.get("title", path), named)
    return 0


if __name__ == "__main__":
    sys.exit(main())
